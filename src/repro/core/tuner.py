"""Tuner orchestrator (paper Fig. 4), completion-driven edition.

Algorithm-selection switch + iteration budget (paper: 50) **or**
wall-clock budget + memoized objective + checkpoint/resume.

The default loop (``loop="async"``) is a completion-driven scheduler:
the engine is asked for enough candidates to fill every free worker, the
:class:`EvaluationExecutor` measures them concurrently, and the moment
*any* evaluation completes its result is ``tell``-ed back and a single
replacement point is asked — so engines see results in completion order
(BO refreshes its candidate set per completion, the GA inserts
steady-state, Nelder-Mead reconciles speculative probes that finish
late) and no worker ever idles at a batch barrier behind one slow
configuration.  ``loop="batch"`` keeps the legacy per-batch barrier for
comparison (see ``benchmarks/perf_iterations.py --async-loop``).

``parallelism=1`` (the default) uses the serial executor and both loops
degenerate to the historical one-point-per-iteration sequence, which
reproduces the seed trace bit-for-bit for the same seed (pinned by
``tests/golden/ask_tell_traces.json``).

The wall-clock budget bounds *in-flight* work, not just the gaps between
completions: the deadline is threaded into the executor's wait machinery
(the same plumbing that enforces per-evaluation timeouts), and work
still unfinished when it passes is **abandoned** — nothing recorded,
nothing cached, the run stops on time.  When a wall-clock budget is
configured, ``parallelism=1`` automatically uses a 1-worker thread pool
instead of the serial backend, since only a pool can abandon a running
evaluation; an explicitly forced ``executor_backend="serial"`` can still
only stop *between* evaluations, never mid-measurement.

``memo_cache_path`` backs the executor's memo cache with an on-disk JSON
store (atomic writes + cross-process file locking), so a re-run or a
resumed run of the same tuning job re-evaluates nothing and multiple
hosts sharing a filesystem reuse each other's measurements.

``workers=["host:port", ...]`` (or ``executor_backend="remote"``) farms
the measurements to ``launch/worker.py`` daemons over the RPC protocol
in ``repro.tuning.remote``: the completion-driven loop sizes its
in-flight window to the fleet's registered slot total, a worker death
reinjects its in-flight measurements onto survivors (never recorded as
config failures), and every result still lands in the same memo cache —
written by *this* process, so the worker fleet needs no shared
filesystem.

``multi_fidelity=True`` layers a successive-halving rung scheduler
(ASHA; see ``repro.tuning.fidelity``) over the async loop: fresh
candidates are screened with cheap partial measurements, survivors are
promoted fidelity by fidelity, and in-flight promotions that have been
outclassed are preempted through the executor.  The budget then counts
full-measurement equivalents (sum of completed fidelities), so the
scheduler spends what the same budget of full measurements would have —
just on many more candidates.

Objectives follow the explicit evaluator protocol (``(value, meta)``;
see ``repro.tuning.objective``); plain scalar callables are adapted
automatically.  Failures (OOM, compile error, timeout) surface as
``-inf`` and are recorded, mirroring how a real measurement harness
handles a crashed configuration.
"""
from __future__ import annotations

import math
import pathlib
import threading
import time
from dataclasses import asdict, dataclass, field, fields
from typing import Callable, Dict, List, Optional, Union

import numpy as np

from repro.core.bayesopt import BayesOpt, TransferPrior
from repro.core.engine import Engine
from repro.core.exhaustive import Exhaustive
from repro.core.genetic import GeneticAlgorithm
from repro.core.history import History
from repro.core.neldermead import NelderMead
from repro.core.observation import Observation
from repro.core.random_search import RandomSearch
from repro.core.space import SearchSpace
from repro.tuning.executor import EvalResult, EvaluationExecutor, PendingEval
from repro.tuning.objective import as_evaluator
from repro.tuning.remote import FleetOptions

ENGINES = {
    "bo": BayesOpt,
    "ga": GeneticAlgorithm,
    "nms": NelderMead,
    "random": RandomSearch,
    "exhaustive": Exhaustive,
}

LOOPS = ("async", "batch")


def _check_keys(d: dict, known, what: str) -> None:
    """Loud validation shared by every ``from_dict``: unknown keys raise
    a ValueError naming them (same contract ``config_from_point`` has),
    so a malformed JSON job submission fails at the daemon's front door
    instead of silently tuning with defaults."""
    unknown = sorted(set(d) - set(known))
    if unknown:
        hints = {k: _LEGACY_FLAT_HINTS[k] for k in unknown
                 if k in _LEGACY_FLAT_HINTS}
        hint = ("" if not hints else
                "; flat v1 knobs moved into sub-configs: " + ", ".join(
                    f"{k!r} -> {v!r}" for k, v in hints.items()))
        raise ValueError(
            f"unknown {what} key(s) {unknown}; known: {sorted(known)}{hint}")


@dataclass
class ExecutorConfig:
    """How measurements are executed (the evaluation side of the split).

    ``parallelism``      worker-pool width; 1 == historical sequential loop
    ``backend``          serial|thread|process|remote (auto: serial at
                         parallelism=1, thread above, remote when workers set)
    ``workers``          remote backend: host:port worker daemons
                         (launch/worker.py); parallelism becomes the fleet's
                         registered slot total
    ``eval_timeout``     seconds per evaluation; -inf past it
    ``memo_cache_path``  disk-backed cross-run memo cache
    ``batch_size``       batch loop only: points per ask

    Elastic-fleet knobs (remote backend only; ignored elsewhere so
    local backends stay byte-identical):

    ``fleet_port``          join-socket port kept open for the whole run
                            (0 = ephemeral, None = fixed fleet, no socket)
    ``fleet_homogeneity``   strict (refuse mixed hardware fingerprints) |
                            normalize (admit + calibrate cost_seconds)
    ``speculation``         re-execute stragglers on an idle worker
    ``speculation_factor``  duplicate a task once its age exceeds
                            factor × p95 of observed completions
    ``speculation_min_observations``  completions needed per fidelity
                            before the p95 is trusted
    ``heartbeat_s``         fleet-wide heartbeat default; each worker's
                            stall window is 3 missed beats of its own
                            registered interval
    """

    parallelism: int = 1
    backend: Optional[str] = None
    workers: Optional[List[str]] = None
    eval_timeout: Optional[float] = None
    memo_cache_path: Optional[str] = None
    batch_size: Optional[int] = None
    fleet_port: Optional[int] = 0
    fleet_homogeneity: str = "strict"
    speculation: bool = True
    speculation_factor: float = 4.0
    speculation_min_observations: int = 4
    heartbeat_s: Optional[float] = None

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ExecutorConfig":
        _check_keys(d, {f.name for f in fields(cls)}, "ExecutorConfig")
        return cls(**d)

    def fleet_options(self) -> FleetOptions:
        """Elastic-fleet knobs in `RemoteWorkerPool` form (remote only)."""
        return FleetOptions(
            listen_port=self.fleet_port,
            speculation=self.speculation,
            speculation_factor=self.speculation_factor,
            min_observations=self.speculation_min_observations,
            homogeneity=self.fleet_homogeneity,
            heartbeat_s=self.heartbeat_s,
        )


@dataclass
class HyperBandConfig:
    """HyperBand-specific knobs (``multi_fidelity.scheduler = "hyperband"``).

    ``brackets``  how many ASHA brackets to hedge across (deepest ladders
                  first); ``None`` = every bracket the fidelity range
                  supports, ``s_max + 1``
    """

    brackets: Optional[int] = None

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Optional[dict]) -> "HyperBandConfig":
        if d is None:
            return cls()
        _check_keys(d, {f.name for f in fields(cls)}, "HyperBandConfig")
        return cls(**d)


@dataclass
class PBTConfig:
    """Population-Based Training knobs (``multi_fidelity.scheduler = "pbt"``).

    ``population``        steady-state population size
    ``exploit_quantile``  cull fraction (bottom) == donor fraction (top)
    ``perturb_prob``      per-dimension explore mutation probability
    ``step_fidelity``     fidelity of every PBT step (``None`` =
                          ``multi_fidelity.min_fidelity``)
    """

    population: int = 6
    exploit_quantile: float = 0.25
    perturb_prob: float = 0.25
    step_fidelity: Optional[float] = None

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Optional[dict]) -> "PBTConfig":
        if d is None:
            return cls()
        _check_keys(d, {f.name for f in fields(cls)}, "PBTConfig")
        return cls(**d)


@dataclass
class MultiFidelityConfig:
    """Budget-allocation scheduler knobs; ``enabled=False`` = plain loop.

    ``enabled``           route the run through the scheduler driver;
                          budget then counts full-measurement
                          *equivalents* (sum of fidelities), not
                          evaluations
    ``scheduler``         asha (successive halving, default) | hyperband
                          (bracket hedging) | pbt (population-based
                          training) — see ``repro.tuning.schedulers``
    ``eta``               rung reduction factor (fidelity ratio + survivor
                          fraction 1/eta between adjacent rungs)
    ``min_fidelity``      bottom-rung fidelity floor (and the default PBT
                          step fidelity)
    ``promote_quantile``  per-rung survivor quantile (default 1/eta)
    ``preempt``           kill in-flight work the scheduler has since
                          declared pointless (executor preempt:
                          cancelled if unstarted, recorded normally if
                          already running)
    ``hyperband``/``pbt`` per-scheduler sub-configs
    """

    enabled: bool = False
    eta: float = 3.0
    min_fidelity: float = 0.1
    promote_quantile: Optional[float] = None
    preempt: bool = True
    scheduler: str = "asha"
    hyperband: HyperBandConfig = field(default_factory=HyperBandConfig)
    pbt: PBTConfig = field(default_factory=PBTConfig)

    def __bool__(self) -> bool:
        # ``if config.multi_fidelity:`` predates the sub-config and must
        # keep meaning "is multi-fidelity on", not "is the object present"
        return self.enabled

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Union[dict, bool]) -> "MultiFidelityConfig":
        if isinstance(d, bool):  # submissions may spell it as a plain flag
            return cls(enabled=d)
        _check_keys(d, {f.name for f in fields(cls)}, "MultiFidelityConfig")
        kw = {k: v for k, v in d.items() if k not in ("hyperband", "pbt")}
        return cls(hyperband=HyperBandConfig.from_dict(d.get("hyperband")),
                   pbt=PBTConfig.from_dict(d.get("pbt")), **kw)


@dataclass
class TransferConfig:
    """Transfer learning across tuning jobs (see ``repro.tuning.corpus``).

    ``corpus_path``    persistent observation-corpus JSON file; ``None``
                       disables transfer entirely (the bit-for-bit path)
    ``job_id``         provenance id stamped on records this job writes
                       (auto-generated when unset)
    ``warm_start``     seed the BO surrogate with neighbor-workload rows
                       under inflated, decaying observation noise
    ``prefilter``      over-ask the engine and measure only the
                       top-``keep_fraction`` of candidates by
                       corpus-predicted score (all engines that declare
                       ``prefilter_safe``)
    ``k_neighbors``    nearest neighbor workloads consulted
    ``max_prior``      max prior rows seeded into the surrogate
    ``max_distance``   workload-distance cutoff: beyond it a workload is
                       not a neighbor and contributes nothing
    ``keep_fraction``  fraction of an over-asked batch actually measured
    ``decay_evals``    real observations after which the prior retires
    ``guard_evals``    finite real observations before the
                       negative-transfer agreement check runs
    """

    corpus_path: Optional[str] = None
    job_id: Optional[str] = None
    warm_start: bool = True
    prefilter: bool = True
    k_neighbors: int = 3
    max_prior: int = 32
    max_distance: float = 0.35
    keep_fraction: float = 0.4
    decay_evals: int = 24
    guard_evals: int = 3

    def __bool__(self) -> bool:
        # ``if config.transfer:`` means "is transfer configured", matching
        # the MultiFidelityConfig convention
        return self.corpus_path is not None

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Optional[dict]) -> "TransferConfig":
        if d is None:
            return cls()
        _check_keys(d, {f.name for f in fields(cls)}, "TransferConfig")
        return cls(**d)


#: where each pre-v2 flat TunerConfig knob lives now (drives from_dict's
#: error hints and the constructor's backward-compatible keyword shim)
_LEGACY_FLAT_HINTS = {
    "parallelism": "executor.parallelism",
    "batch_size": "executor.batch_size",
    "executor_backend": "executor.backend",
    "workers": "executor.workers",
    "eval_timeout": "executor.eval_timeout",
    "memo_cache_path": "executor.memo_cache_path",
    "mf_eta": "multi_fidelity.eta",
    "mf_min_fidelity": "multi_fidelity.min_fidelity",
    "mf_promote_quantile": "multi_fidelity.promote_quantile",
    "mf_preempt": "multi_fidelity.preempt",
}


class TunerConfig:
    """Tuner configuration, v2: nested sub-configs instead of a flat knob
    pile.  Execution knobs live in :class:`ExecutorConfig` (``executor=``)
    and successive-halving knobs in :class:`MultiFidelityConfig`
    (``multi_fidelity=``, which also accepts a plain bool).

    ``from_dict``/``to_dict`` are the JSON contract the tuning service
    validates job submissions against: unknown keys raise ``ValueError``
    naming them (nothing is silently dropped).

    The pre-v2 flat spellings (``parallelism=``, ``mf_eta=``, ...) are
    still accepted as constructor keywords and readable/writable as
    attributes — they delegate to the nested sub-configs, so the two
    spellings can never disagree.  ``from_dict`` accepts only the v2
    schema and names the new home of any flat key it rejects.
    """

    def __init__(self, algorithm: str = "bo",
                 budget: int = 50,  # paper: tuning iterations capped at 50
                 seed: int = 0,
                 checkpoint_path: Optional[str] = None,
                 engine_kwargs: Optional[dict] = None,
                 verbose: bool = True,
                 loop: str = "async",  # async (completion-driven) |
                 # batch (legacy barrier)
                 wall_clock_budget: Optional[float] = None,  # secs;
                 # unfinished work is abandoned at the deadline (forces a
                 # pool backend unless overridden)
                 cost_aware: bool = False,  # BO: EI-per-second acquisition
                 executor: Optional[ExecutorConfig] = None,
                 multi_fidelity: Union[MultiFidelityConfig, bool] = False,
                 transfer: Optional[TransferConfig] = None,
                 **legacy):
        self.algorithm = algorithm
        self.budget = budget
        self.seed = seed
        self.checkpoint_path = checkpoint_path
        self.engine_kwargs = dict(engine_kwargs or {})
        self.verbose = verbose
        self.loop = loop
        self.wall_clock_budget = wall_clock_budget
        self.cost_aware = cost_aware
        self.executor = executor if executor is not None else ExecutorConfig()
        self.multi_fidelity = (multi_fidelity if isinstance(
            multi_fidelity, MultiFidelityConfig)
            else MultiFidelityConfig(enabled=bool(multi_fidelity)))
        self.transfer = transfer if transfer is not None else TransferConfig()
        unknown = sorted(set(legacy) - set(_LEGACY_FLAT_HINTS))
        if unknown:
            raise TypeError(f"TunerConfig got unexpected keyword(s) {unknown}")
        for k, v in legacy.items():  # flat v1 spellings -> nested homes
            setattr(self, k, v)

    def to_dict(self) -> dict:
        return {
            "algorithm": self.algorithm, "budget": self.budget,
            "seed": self.seed, "checkpoint_path": self.checkpoint_path,
            "engine_kwargs": dict(self.engine_kwargs),
            "verbose": self.verbose, "loop": self.loop,
            "wall_clock_budget": self.wall_clock_budget,
            "cost_aware": self.cost_aware,
            "executor": self.executor.to_dict(),
            "multi_fidelity": self.multi_fidelity.to_dict(),
            "transfer": self.transfer.to_dict(),
        }

    _TOP_LEVEL_KEYS = ("algorithm", "budget", "seed", "checkpoint_path",
                       "engine_kwargs", "verbose", "loop",
                       "wall_clock_budget", "cost_aware", "executor",
                       "multi_fidelity", "transfer")

    @classmethod
    def from_dict(cls, d: dict) -> "TunerConfig":
        _check_keys(d, cls._TOP_LEVEL_KEYS, "TunerConfig")
        kw = {k: v for k, v in d.items()
              if k not in ("executor", "multi_fidelity", "transfer")}
        return cls(executor=ExecutorConfig.from_dict(d.get("executor") or {}),
                   multi_fidelity=MultiFidelityConfig.from_dict(
                       d.get("multi_fidelity", False)),
                   transfer=TransferConfig.from_dict(d.get("transfer")),
                   **kw)

    def __repr__(self) -> str:
        return f"TunerConfig({self.to_dict()!r})"

    def __eq__(self, other) -> bool:
        return (isinstance(other, TunerConfig)
                and self.to_dict() == other.to_dict())

    # -- flat v1 attribute compatibility (delegates to the sub-configs) ------
    parallelism = property(
        lambda s: s.executor.parallelism,
        lambda s, v: setattr(s.executor, "parallelism", v))
    batch_size = property(
        lambda s: s.executor.batch_size,
        lambda s, v: setattr(s.executor, "batch_size", v))
    executor_backend = property(
        lambda s: s.executor.backend,
        lambda s, v: setattr(s.executor, "backend", v))
    workers = property(
        lambda s: s.executor.workers,
        lambda s, v: setattr(s.executor, "workers", v))
    eval_timeout = property(
        lambda s: s.executor.eval_timeout,
        lambda s, v: setattr(s.executor, "eval_timeout", v))
    memo_cache_path = property(
        lambda s: s.executor.memo_cache_path,
        lambda s, v: setattr(s.executor, "memo_cache_path", v))
    mf_eta = property(
        lambda s: s.multi_fidelity.eta,
        lambda s, v: setattr(s.multi_fidelity, "eta", v))
    mf_min_fidelity = property(
        lambda s: s.multi_fidelity.min_fidelity,
        lambda s, v: setattr(s.multi_fidelity, "min_fidelity", v))
    mf_promote_quantile = property(
        lambda s: s.multi_fidelity.promote_quantile,
        lambda s, v: setattr(s.multi_fidelity, "promote_quantile", v))
    mf_preempt = property(
        lambda s: s.multi_fidelity.preempt,
        lambda s, v: setattr(s.multi_fidelity, "preempt", v))


class Tuner:
    def __init__(
        self,
        objective: Callable[[Dict], float],
        space: SearchSpace,
        config: TunerConfig = TunerConfig(),
        executor: Optional[EvaluationExecutor] = None,
    ):
        self.objective = as_evaluator(objective)
        self.space = space
        self.config = config
        #: cooperative cancellation (the tuning service's ``cancel_job``):
        #: every loop checks this between completions and exits cleanly —
        #: recorded history and checkpoints stay intact, in-flight work is
        #: abandoned exactly like a wall-clock expiry
        self._stop = threading.Event()
        if config.algorithm not in ENGINES:
            raise ValueError(
                f"unknown algorithm {config.algorithm!r}; one of {sorted(ENGINES)}"
            )
        if config.loop not in LOOPS:
            raise ValueError(f"unknown loop {config.loop!r}; one of {LOOPS}")
        engine_kwargs = dict(config.engine_kwargs)
        if config.cost_aware:
            if config.algorithm != "bo":
                raise ValueError(
                    "cost_aware acquisition is a BayesOpt feature "
                    f"(algorithm={config.algorithm!r})")
            engine_kwargs.setdefault("cost_aware", True)
        if config.multi_fidelity:
            if config.loop != "async":
                raise ValueError(
                    "multi_fidelity requires the completion-driven loop "
                    f"(loop={config.loop!r}): rung promotion and preemption "
                    "are decided per completion, which a batch barrier "
                    "cannot express")
            from repro.tuning.schedulers import SCHEDULER_KINDS
            if getattr(config.multi_fidelity,
                       "scheduler", "asha") not in SCHEDULER_KINDS:
                raise ValueError(
                    f"unknown multi_fidelity.scheduler "
                    f"{config.multi_fidelity.scheduler!r}; "
                    f"one of {SCHEDULER_KINDS}")
            if config.algorithm == "bo":
                # partial observations enter the surrogate with a fidelity
                # feature, never as exact values
                engine_kwargs.setdefault("fidelity_feature", True)
        # -- transfer learning: resolve the corpus + prior BEFORE the engine
        # is constructed, so the warm-start prior can enter its kwargs.  No
        # corpus configured -> corpus is None, nothing below runs, and the
        # engine/executor construction is byte-identical to the historical
        # path.
        tr = config.transfer
        corpus = (getattr(executor, "corpus", None)
                  if executor is not None else None)
        if corpus is None and tr:
            from repro.tuning.corpus import TuningCorpus
            corpus = TuningCorpus(tr.corpus_path, job_id=tr.job_id)
        self.corpus = corpus
        self._transfer_prior: Optional[TransferPrior] = None
        if corpus is not None:
            corpus.describe_job(self.objective, space)
            rows = corpus.prior_observations(
                space, corpus.descriptor["features"],
                k=tr.k_neighbors, max_rows=tr.max_prior,
                max_distance=tr.max_distance)
            if rows:
                self._transfer_prior = TransferPrior.from_rows(space, rows)
                if tr.warm_start and config.algorithm == "bo":
                    engine_kwargs.setdefault("transfer_prior",
                                             self._transfer_prior)
                    engine_kwargs.setdefault("transfer_decay", tr.decay_evals)
                    engine_kwargs.setdefault("transfer_guard_n",
                                             tr.guard_evals)
        self.engine: Engine = ENGINES[config.algorithm](
            space, seed=config.seed, **engine_kwargs
        )
        # corpus pre-filter (all prefilter_safe engines): guard state is
        # independent of the BO-internal prior guard
        self._prefilter_on = (bool(tr) and tr.prefilter
                              and self._transfer_prior is not None
                              and getattr(ENGINES[config.algorithm],
                                          "prefilter_safe", True))
        self._prefilter_checked = False
        if executor is not None:
            # the tuning service multiplexes many jobs over one shared
            # worker fleet: each job's Tuner gets a pre-built executor
            # (wrapping the shared pool) instead of constructing its own
            self.executor = executor
            if corpus is not None and getattr(executor, "corpus", None) is None:
                # service-injected executors are per-job: attach the
                # corpus so their finalized measurements are recorded
                executor.corpus = corpus
        else:
            backend = config.executor.backend
            if backend is None and config.executor.workers:
                backend = "remote"
            if backend is None and config.wall_clock_budget is not None:
                # the serial backend cannot abandon a running evaluation, so
                # a wall-clock budget needs a pool even at parallelism=1
                backend = "thread"
            self.executor = EvaluationExecutor(
                self.objective, space,
                parallelism=config.executor.parallelism,
                backend=backend,
                timeout=config.executor.eval_timeout,
                cache_path=config.executor.memo_cache_path,
                workers=config.executor.workers,
                corpus=corpus,
                # elastic-fleet knobs only reach a pool we build ourselves;
                # local backends never see them (byte-identical traces)
                fleet=(config.executor.fleet_options()
                       if backend == "remote" else None),
            )
        self.history = History(space)
        self.rung_scheduler = None  # set by the multi-fidelity loop
        if config.checkpoint_path and pathlib.Path(config.checkpoint_path).exists():
            self._resume(config.checkpoint_path)

    def _resume(self, path: str) -> None:
        """Fault tolerance: reload history + replay it into the engine.

        A checkpoint only ever contains completed evaluations (points
        still in flight when the run died are excluded from
        ``History.save``), so resuming mid-stream simply re-evaluates
        whatever had not finished — or pulls it straight from the
        disk-backed memo cache if it completed after the checkpoint.

        Replay goes through ``tell`` (one call with the whole trace), not
        raw per-point ``observe``: engines with speculative batches
        (Nelder-Mead) buffer the results and consume only the points
        their state machine actually reaches, in order — feeding
        unconsumed speculative probes into ``observe`` would corrupt the
        state machine.
        """
        loaded = History.load(path, self.space)
        obs = loaded.observations()
        self.history.add_observations(obs)
        self.engine.tell(obs)
        if self.config.verbose and len(loaded):
            print(f"[tuner] resumed {len(loaded)} evaluations from {path}")

    # -- shared helpers ------------------------------------------------------
    def _report(self, r: EvalResult) -> None:
        if not self.config.verbose:
            return
        best = (self.history.best().value
                if any(math.isfinite(e.value) for e in self.history.evals)
                else float("nan"))
        print(
            f"[tuner:{self.engine.name}] it={len(self.history):3d} "
            f"y={r.value:.4g} best={best:.4g} "
            f"({r.cost_seconds:.1f}s) {r.point}"
        )

    def _record(self, r: EvalResult, fidelity: float = 1.0,
                rung: Optional[int] = None,
                lineage: Optional[str] = None) -> None:
        """tell + append + checkpoint for one completed evaluation."""
        obs = Observation(point=r.point, value=r.value,
                          cost_seconds=r.cost_seconds, fidelity=fidelity,
                          rung=rung, lineage=lineage, meta=r.meta)
        self.engine.tell([obs])
        self.history.add_observations([obs])
        if self.config.checkpoint_path:
            self.history.save(self.config.checkpoint_path)
        self._report(r)

    def _ask_filtered(self, want: int, history: History) -> List[Dict]:
        """Engine ask, routed through the corpus pre-filter when active.

        With transfer configured and a prior available, the engine is
        over-asked by ``1/keep_fraction`` and only the candidates the
        neighbor-workload observations rank highest are measured — the
        corpus-trained pre-filter that works for *every* engine that
        declares ``prefilter_safe`` (AutoTVM-style: spend measurements
        only on candidates history says are promising).  Inactive (no
        corpus, unsafe engine, prior retired or guard-tripped), this is
        exactly ``engine.ask``.
        """
        tr = self.config.transfer
        prior = self._transfer_prior
        if (not self._prefilter_on or prior is None or want <= 0
                or len(history) >= tr.decay_evals):
            return self.engine.ask(want, history)
        # negative-transfer guard, independent of BO's internal one: stop
        # filtering permanently if the prior mis-ranks real measurements
        if not self._prefilter_checked:
            X, y = history.encoded()
            finite = np.isfinite(y)
            if int(finite.sum()) >= tr.guard_evals:
                self._prefilter_checked = True
                from repro.tuning.corpus import prediction_agreement
                agree = prediction_agreement(prior.predict(X[finite]),
                                             y[finite])
                if agree is not None and agree < 0.0:
                    self._prefilter_on = False
                    return self.engine.ask(want, history)
        ask_n = max(want, math.ceil(want / max(tr.keep_fraction, 1e-9)))
        cands = self.engine.ask(ask_n, history)
        if len(cands) <= want:
            return cands
        # an engine that padded the tail of an exhausted candidate pool
        # with unranked random fills reports the ranked head via
        # ``last_ask_ranked`` (warm-started BO): only the head competes
        # under the prior's score, so a random fill scored by the same
        # prior can never displace a candidate the engine actually
        # ranked — fills may only top up a deficit, in engine order
        ranked_n = getattr(self.engine, "last_ask_ranked", None)
        if ranked_n is None or not 0 <= ranked_n <= len(cands):
            ranked_n = len(cands)
        if ranked_n <= want:
            return cands[:want]  # whole ranked head survives + fills
        scores = prior.predict(self.space.encode_many(cands[:ranked_n]))
        top = np.argsort(-scores, kind="stable")[:want]
        # keep the engine's own proposal order among survivors (for BO
        # that is acquisition-descending)
        return [cands[i] for i in sorted(top.tolist())]

    def _wall_clock_exhausted(self, wall_clock: Optional[float]) -> None:
        if self.config.verbose:
            print(f"[tuner:{self.engine.name}] wall-clock budget "
                  f"({wall_clock:.1f}s) exhausted at "
                  f"{len(self.history)} evaluations")

    # -- cooperative cancellation (tuning service: cancel_job) ---------------
    def request_stop(self) -> None:
        """Ask a running ``run()`` to exit at the next completion.

        Thread-safe and idempotent.  Everything recorded so far stays
        recorded (and checkpointed); in-flight measurements are abandoned
        unrecorded, exactly like a wall-clock expiry, so a stopped run can
        later be resumed from its checkpoint without loss."""
        self._stop.set()

    @property
    def stop_requested(self) -> bool:
        return self._stop.is_set()

    # -- completion-driven loop (default) ------------------------------------
    def _run_async(self, budget: int, wall_clock: Optional[float]) -> History:
        t_start = time.time()
        deadline = t_start + wall_clock if wall_clock is not None else None
        outstanding: List[PendingEval] = []
        try:
            while len(self.history) < budget and not self._stop.is_set():
                if deadline is not None and time.time() >= deadline:
                    self._wall_clock_exhausted(wall_clock)
                    break
                # refill: one ask per free worker slot, the moment it frees
                # (executor.parallelism, not config: the remote backend's
                # capacity is the fleet's registered slot total)
                capacity = self.executor.parallelism - len(outstanding)
                want = min(capacity,
                           budget - len(self.history) - len(outstanding))
                asked_any = False
                if want > 0:
                    if deadline is not None:  # budget pressure -> cost-aware BO
                        self.engine.note_budget(
                            max(0.0, (deadline - time.time()) / wall_clock))
                    points = self._ask_filtered(want, self.history)
                    asked_any = bool(points)
                    submitted = []
                    for p in points[:want]:
                        cached = self.history.lookup(p)
                        if cached is not None:
                            # memoized repeat query: free, told immediately
                            self._record(EvalResult(dict(p), cached.value,
                                                    0.0, {"memoized": True}))
                            continue
                        if self.history.pending(p):
                            continue  # its measurement is already in flight
                        submitted.append(p)
                    if submitted:
                        self.history.mark_inflight(submitted)
                        outstanding.extend(self.executor.submit(submitted))
                if len(self.history) >= budget:
                    break
                if not outstanding:
                    if not asked_any:
                        break  # engine has nothing left to propose
                    continue  # asks were all memo hits; go ask again
                done = self.executor.next_completed(outstanding,
                                                    deadline=deadline)
                if done is None:  # deadline passed while waiting
                    self._wall_clock_exhausted(wall_clock)
                    break
                outstanding.remove(done)
                self._record(done.result())
        finally:
            # abandoned in-flight points (wall-clock expiry / hard abort)
            # must not leave stale pending marks behind; anything still
            # marked here is by definition unmeasured (add() unmarks on
            # completion), so clearing the whole set is exact
            self.history.clear_inflight()
        return self.history

    # -- multi-fidelity successive-halving loop ------------------------------
    def _run_multi_fidelity(self, budget: int,
                            wall_clock: Optional[float]) -> History:
        """Completion-driven ASHA on top of the async machinery.

        Fresh engine candidates enter at the bottom rung (cheap partial
        measurements); completions in the top ``1/mf_eta`` of their rung
        are resubmitted at the next fidelity the moment a worker frees,
        and in-flight promotions whose source rung has since outclassed
        them are preempted (cancelled when still queued; recorded
        normally when a worker already started — exactly-once either
        way).  ``budget`` counts full-measurement *equivalents*: the sum
        of completed fidelities, so ``budget=50`` spends what 50 full
        measurements would have.

        Every completion — partial or full — lands in history with its
        fidelity and is told to the engine (BO reads the fidelity column
        as a surrogate feature; ranking engines use partial values as
        ASHA does).  ``history.best(full_fidelity_only=True)`` is the
        trustworthy incumbent.

        An objective without fidelity support cannot cheapen a
        measurement, so for the *ladder* schedulers (asha, hyperband)
        rungs would all cost the same and "promotion" would just
        re-measure points: those degenerate to the plain
        completion-driven loop.  PBT is not a ladder — its steps measure
        *mutating* points (optionally warm-started via checkpoint-fork),
        so it runs regardless of fidelity support.
        """
        from repro.tuning.schedulers import build_scheduler

        cfg = self.config
        mf = cfg.multi_fidelity
        kind = getattr(mf, "scheduler", "asha") or "asha"
        if (kind != "pbt"
                and not getattr(self.objective, "supports_fidelity", False)):
            if self.config.verbose:
                print("[tuner] objective has no fidelity support; "
                      "multi_fidelity degenerates to the async loop")
            return self._run_async(budget, wall_clock)

        sched = build_scheduler(mf, space=self.space, seed=cfg.seed)
        # observability (bench/service stats).  The attribute name
        # predates the scheduler zoo; it now holds whichever
        # TrialScheduler drives the run.
        self.rung_scheduler = sched
        t_start = time.time()
        deadline = t_start + wall_clock if wall_clock is not None else None
        outstanding: List[PendingEval] = []
        spend = 0.0  # full-measurement equivalents consumed
        # checkpoint resume: rebuild scheduler state (rung results AND
        # promotion marks for the ladders, population/lineages for PBT —
        # see each scheduler's ``replay``) and budget accounting from the
        # replayed history.  The scheduler owns the charge: duplicates
        # and preempted placeholders replay at 0.0 spend.
        for e in self.history.evals:
            spend += sched.replay(
                self.space.key(e.point), e.point, e.value, e.fidelity,
                rung=getattr(e, "rung", None),
                lineage=getattr(e, "lineage", None), meta=e.meta)

        def consume(done: PendingEval) -> None:
            nonlocal spend
            r = done.result()
            if r.meta.get("preempted"):
                return  # cancelled pre-start: nothing was measured
            rung = done.rung if done.rung is not None else 0
            # budget and history record what was *delivered*, not what the
            # rung asked for: the executor upgrades requests the evaluator
            # cannot serve partially (meta["fidelity"] / a normalized
            # pending fidelity say so) and those must be charged — and
            # trusted — as full measurements
            fid = r.meta.get("fidelity")
            if fid is None:
                fid = 1.0 if done.fidelity is None else done.fidelity
            fid = float(fid)
            spend += fid  # memo hits count too: budget is logical spend
            sched.on_result(self.space.key(done.point), done.point,
                            r.value, rung, fidelity=fid, meta=r.meta,
                            lineage=done.lineage)
            self._record(r, fidelity=fid, rung=rung, lineage=done.lineage)

        def dispatch(act) -> PendingEval:
            pend = self.executor.submit(
                [act.point], fidelity=act.fidelity, rung=act.rung,
                state=act.state, lineage=act.lineage)[0]
            sched.on_started(self.space.key(act.point), act.point, act.rung,
                             lineage=act.lineage)
            outstanding.append(pend)
            return pend

        try:
            while spend < budget and not self._stop.is_set():
                if deadline is not None and time.time() >= deadline:
                    self._wall_clock_exhausted(wall_clock)
                    break
                capacity = self.executor.parallelism - len(outstanding)
                submitted_any = False
                # scheduler-driven work outranks fresh probes for free
                # workers: a survivor's next rung (or a PBT member's next
                # step/fork) is the highest-value measurement the policy
                # currently knows how to ask for
                while capacity > 0:
                    act = sched.next_action()
                    if act is None:
                        break
                    dispatch(act)
                    capacity -= 1
                    submitted_any = True
                fresh = min(capacity, sched.fresh_quota(capacity))
                if fresh > 0:
                    if deadline is not None:
                        self.engine.note_budget(
                            max(0.0, (deadline - time.time()) / wall_clock))
                    points = self._ask_filtered(fresh, self.history)
                    for p in points[:fresh]:
                        if self.history.seen(p) or self.history.pending(p):
                            continue  # known at some rung / already in flight
                        act = sched.admit(self.space.key(p), p)
                        if act is None:
                            continue  # refused (e.g. PBT population full)
                        dispatch(act)
                        self.history.mark_inflight([p])
                        submitted_any = True
                # preemption scan: work the scheduler has since declared
                # pointless — an ASHA/HyperBand promotion whose source-rung
                # value fell below the current cutoff, a PBT step of a
                # culled member — cannot win anything by finishing
                if mf.preempt:
                    for pend in list(outstanding):
                        if (pend.preempted or pend.done()
                                or sched.decide(self.space.key(pend.point),
                                                pend.rung or 0,
                                                lineage=pend.lineage)
                                != "preempt"):
                            continue
                        if self.executor.preempt(pend) == "cancelled":
                            outstanding.remove(pend)
                            sched.on_preempted(self.space.key(pend.point),
                                               pend.rung or 0,
                                               lineage=pend.lineage)
                        # "running": the worker got there first; its
                        # result arrives and is recorded normally
                if not outstanding:
                    if not submitted_any:
                        break  # engine exhausted, no promotions possible
                    continue
                done = self.executor.next_completed(outstanding,
                                                    deadline=deadline)
                if done is None:
                    self._wall_clock_exhausted(wall_clock)
                    break
                outstanding.remove(done)
                consume(done)
            # drain: promotions are event-driven, so the loop can have
            # dispatched slightly past the logical budget — those
            # measurements are paid for and must be recorded (exactly-once
            # accounting), never silently dropped.  A wall-clock deadline
            # still wins: past it, next_completed abandons as usual; a
            # stop request likewise abandons the drain (cancel semantics
            # match wall-clock expiry: in-flight work is re-measured by a
            # resumed run, never lost from the record).
            while outstanding and not self._stop.is_set():
                done = self.executor.next_completed(outstanding,
                                                    deadline=deadline)
                if done is None:
                    break  # deadline: in-flight work is abandoned unrecorded
                outstanding.remove(done)
                consume(done)
        finally:
            self.history.clear_inflight()
        return self.history

    # -- legacy batch-barrier loop -------------------------------------------
    def _evaluate_batch(self, points: List[Dict],
                        deadline: Optional[float] = None) -> List[EvalResult]:
        """History-memoized repeats are free; the rest go to the executor."""
        results: List[Optional[EvalResult]] = [None] * len(points)
        miss_idx, miss_points = [], []
        for i, p in enumerate(points):
            cached = self.history.lookup(p)
            if cached is not None:  # memoized repeat query (engines may revisit)
                results[i] = EvalResult(dict(p), cached.value, 0.0,
                                        {"memoized": True})
            else:
                miss_idx.append(i)
                miss_points.append(p)
        if miss_points:
            for i, r in zip(miss_idx,
                            self.executor.evaluate(miss_points,
                                                   deadline=deadline)):
                results[i] = r
        return results

    def _run_batch(self, budget: int, wall_clock: Optional[float]) -> History:
        batch_size = (self.config.executor.batch_size
                      or max(1, self.executor.parallelism))
        t_start = time.time()
        deadline = t_start + wall_clock if wall_clock is not None else None
        while len(self.history) < budget and not self._stop.is_set():
            if deadline is not None and time.time() >= deadline:
                self._wall_clock_exhausted(wall_clock)
                break
            if deadline is not None:  # budget pressure -> cost-aware BO
                self.engine.note_budget(
                    max(0.0, (deadline - time.time()) / wall_clock))
            points = self._ask_filtered(
                min(batch_size, budget - len(self.history)), self.history)
            if not points:
                break  # engine has nothing left to propose
            self.history.mark_inflight(points)
            try:
                results = self._evaluate_batch(points, deadline=deadline)
            finally:
                self.history.clear_inflight(points)
            # a None slot was abandoned at the wall-clock deadline: it was
            # never measured, so it enters neither the engine nor history
            done = [(p, r) for p, r in zip(points, results) if r is not None]
            if done:
                rs = [r for _, r in done]
                obs = [Observation(point=p, value=r.value,
                                   cost_seconds=r.cost_seconds, meta=r.meta)
                       for p, r in done]
                self.engine.tell(obs)
                self.history.add_observations(obs)
                if self.config.checkpoint_path:
                    self.history.save(self.config.checkpoint_path)
                if self.config.verbose:
                    for r in rs:
                        self._report(r)
        return self.history

    def run(self, budget: Optional[int] = None,
            wall_clock: Optional[float] = None) -> History:
        budget = budget if budget is not None else self.config.budget
        wall_clock = (wall_clock if wall_clock is not None
                      else self.config.wall_clock_budget)
        if (wall_clock is not None and self.executor.backend == "serial"
                and self.config.executor.backend is None):
            # a wall-clock budget supplied at run() time needs the same
            # pool fallback __init__ applies for a configured one: the
            # serial backend cannot abandon a running evaluation.  The
            # memo cache (and its disk store) carries over.
            old = self.executor
            self.executor = EvaluationExecutor(
                self.objective, self.space,
                parallelism=self.config.executor.parallelism,
                backend="thread",
                timeout=self.config.executor.eval_timeout, cache=old.cache,
                corpus=getattr(old, "corpus", None))
            old.close()
        if self.config.multi_fidelity:
            return self._run_multi_fidelity(budget, wall_clock)
        if self.config.loop == "batch":
            return self._run_batch(budget, wall_clock)
        return self._run_async(budget, wall_clock)

    def close(self) -> None:
        self.executor.close()
