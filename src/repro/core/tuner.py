"""Tuner orchestrator (paper Fig. 4).

Algorithm-selection switch + iteration budget (paper: 50) + memoized
objective + checkpoint/resume.  The objective maps a point (dict of
backend-parameter values) to a throughput (higher is better); failures
(OOM, compile error) surface as -inf and are recorded, mirroring how a
real measurement harness handles a crashed configuration.
"""
from __future__ import annotations

import math
import pathlib
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from repro.core.bayesopt import BayesOpt
from repro.core.engine import Engine
from repro.core.exhaustive import Exhaustive
from repro.core.genetic import GeneticAlgorithm
from repro.core.history import History
from repro.core.neldermead import NelderMead
from repro.core.random_search import RandomSearch
from repro.core.space import SearchSpace

ENGINES = {
    "bo": BayesOpt,
    "ga": GeneticAlgorithm,
    "nms": NelderMead,
    "random": RandomSearch,
    "exhaustive": Exhaustive,
}


@dataclass
class TunerConfig:
    algorithm: str = "bo"
    budget: int = 50  # paper: tuning iterations capped at 50
    seed: int = 0
    checkpoint_path: Optional[str] = None
    engine_kwargs: dict = field(default_factory=dict)
    verbose: bool = True


class Tuner:
    def __init__(
        self,
        objective: Callable[[Dict], float],
        space: SearchSpace,
        config: TunerConfig = TunerConfig(),
    ):
        self.objective = objective
        self.space = space
        self.config = config
        if config.algorithm not in ENGINES:
            raise ValueError(
                f"unknown algorithm {config.algorithm!r}; one of {sorted(ENGINES)}"
            )
        self.engine: Engine = ENGINES[config.algorithm](
            space, seed=config.seed, **config.engine_kwargs
        )
        self.history = History(space)
        if config.checkpoint_path and pathlib.Path(config.checkpoint_path).exists():
            self._resume(config.checkpoint_path)

    def _resume(self, path: str) -> None:
        """Fault tolerance: reload history + replay it into the engine."""
        loaded = History.load(path, self.space)
        for ev in loaded.evals:
            self.history.add(ev.point, ev.value, ev.cost_seconds, ev.meta)
            self.engine.observe(ev.point, ev.value)
        if self.config.verbose and len(loaded):
            print(f"[tuner] resumed {len(loaded)} evaluations from {path}")

    def _evaluate(self, point: Dict) -> (float, float, dict):
        cached = self.history.lookup(point)
        if cached is not None:  # memoized repeat query (engines may revisit)
            return cached.value, 0.0, {"memoized": True}
        t0 = time.time()
        try:
            value = self.objective(point)
            meta = {}
            if isinstance(value, tuple):
                value, meta = value
            value = float(value)
        except Exception as e:  # failed configuration = worst outcome
            value, meta = -math.inf, {"error": repr(e)}
        return value, time.time() - t0, meta

    def run(self, budget: Optional[int] = None) -> History:
        budget = budget if budget is not None else self.config.budget
        while len(self.history) < budget:
            point = self.engine.suggest(self.history)
            value, secs, meta = self._evaluate(point)
            self.engine.observe(point, value)
            self.history.add(point, value, secs, meta)
            if self.config.checkpoint_path:
                self.history.save(self.config.checkpoint_path)
            if self.config.verbose:
                best = (self.history.best().value
                        if any(math.isfinite(e.value) for e in self.history.evals)
                        else float("nan"))
                print(
                    f"[tuner:{self.engine.name}] it={len(self.history):3d} "
                    f"y={value:.4g} best={best:.4g} ({secs:.1f}s) {point}"
                )
        return self.history
