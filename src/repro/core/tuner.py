"""Tuner orchestrator (paper Fig. 4), batched ask/tell edition.

Algorithm-selection switch + iteration budget (paper: 50) **or**
wall-clock budget + memoized objective + checkpoint/resume.  Each round
the engine is *asked* for a batch of candidate points, the batch is
measured by the parallel :class:`EvaluationExecutor`, and the results
are *told* back — so the measurement side saturates ``parallelism``
workers while the engine thinks once per batch.

``parallelism=1`` (the default) uses the serial executor with batch size
1 and reproduces the historical one-point-per-iteration loop bit-for-bit
for the same seed.  Objectives follow the explicit evaluator protocol
(``(value, meta)``; see ``repro.tuning.objective``); plain scalar
callables are adapted automatically.  Failures (OOM, compile error,
timeout) surface as ``-inf`` and are recorded, mirroring how a real
measurement harness handles a crashed configuration.
"""
from __future__ import annotations

import math
import pathlib
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.bayesopt import BayesOpt
from repro.core.engine import Engine
from repro.core.exhaustive import Exhaustive
from repro.core.genetic import GeneticAlgorithm
from repro.core.history import History
from repro.core.neldermead import NelderMead
from repro.core.random_search import RandomSearch
from repro.core.space import SearchSpace
from repro.tuning.executor import EvalResult, EvaluationExecutor
from repro.tuning.objective import as_evaluator

ENGINES = {
    "bo": BayesOpt,
    "ga": GeneticAlgorithm,
    "nms": NelderMead,
    "random": RandomSearch,
    "exhaustive": Exhaustive,
}


@dataclass
class TunerConfig:
    algorithm: str = "bo"
    budget: int = 50  # paper: tuning iterations capped at 50
    seed: int = 0
    checkpoint_path: Optional[str] = None
    engine_kwargs: dict = field(default_factory=dict)
    verbose: bool = True
    # -- batched evaluation --------------------------------------------------
    parallelism: int = 1  # worker-pool width; 1 == historical sequential loop
    batch_size: Optional[int] = None  # points per ask; default: parallelism
    executor_backend: Optional[str] = None  # serial|thread|process (auto)
    eval_timeout: Optional[float] = None  # seconds per evaluation; -inf past it
    wall_clock_budget: Optional[float] = None  # seconds; stops between batches


class Tuner:
    def __init__(
        self,
        objective: Callable[[Dict], float],
        space: SearchSpace,
        config: TunerConfig = TunerConfig(),
    ):
        self.objective = as_evaluator(objective)
        self.space = space
        self.config = config
        if config.algorithm not in ENGINES:
            raise ValueError(
                f"unknown algorithm {config.algorithm!r}; one of {sorted(ENGINES)}"
            )
        self.engine: Engine = ENGINES[config.algorithm](
            space, seed=config.seed, **config.engine_kwargs
        )
        self.executor = EvaluationExecutor(
            self.objective, space,
            parallelism=config.parallelism,
            backend=config.executor_backend,
            timeout=config.eval_timeout,
        )
        self.history = History(space)
        if config.checkpoint_path and pathlib.Path(config.checkpoint_path).exists():
            self._resume(config.checkpoint_path)

    def _resume(self, path: str) -> None:
        """Fault tolerance: reload history + replay it into the engine.

        A checkpoint only ever contains completed evaluations (in-flight
        points are excluded from ``History.save``), so resuming mid-batch
        simply re-evaluates whatever had not finished.

        Replay goes through ``tell`` (one call with the whole trace), not
        raw per-point ``observe``: engines with speculative batches
        (Nelder-Mead) consume only the points their state machine actually
        asked for, in order — feeding unconsumed speculative probes into
        ``observe`` would corrupt the state machine.
        """
        loaded = History.load(path, self.space)
        for ev in loaded.evals:
            self.history.add(ev.point, ev.value, ev.cost_seconds, ev.meta)
        self.engine.tell([ev.point for ev in loaded.evals],
                         [ev.value for ev in loaded.evals])
        if self.config.verbose and len(loaded):
            print(f"[tuner] resumed {len(loaded)} evaluations from {path}")

    def _evaluate_batch(self, points: List[Dict]) -> List[EvalResult]:
        """History-memoized repeats are free; the rest go to the executor."""
        results: List[Optional[EvalResult]] = [None] * len(points)
        miss_idx, miss_points = [], []
        for i, p in enumerate(points):
            cached = self.history.lookup(p)
            if cached is not None:  # memoized repeat query (engines may revisit)
                results[i] = EvalResult(dict(p), cached.value, 0.0,
                                        {"memoized": True})
            else:
                miss_idx.append(i)
                miss_points.append(p)
        if miss_points:
            for i, r in zip(miss_idx, self.executor.evaluate(miss_points)):
                results[i] = r
        return results

    def run(self, budget: Optional[int] = None,
            wall_clock: Optional[float] = None) -> History:
        budget = budget if budget is not None else self.config.budget
        wall_clock = (wall_clock if wall_clock is not None
                      else self.config.wall_clock_budget)
        batch_size = self.config.batch_size or max(1, self.config.parallelism)
        t_start = time.time()
        while len(self.history) < budget:
            if wall_clock is not None and time.time() - t_start >= wall_clock:
                if self.config.verbose:
                    print(f"[tuner:{self.engine.name}] wall-clock budget "
                          f"({wall_clock:.1f}s) exhausted at "
                          f"{len(self.history)} evaluations")
                break
            points = self.engine.ask(
                min(batch_size, budget - len(self.history)), self.history)
            if not points:
                break  # engine has nothing left to propose
            self.history.mark_inflight(points)
            try:
                results = self._evaluate_batch(points)
            finally:
                self.history.clear_inflight(points)
            self.engine.tell(points, [r.value for r in results])
            self.history.add_batch(
                points, [r.value for r in results],
                [r.cost_seconds for r in results], [r.meta for r in results])
            if self.config.checkpoint_path:
                self.history.save(self.config.checkpoint_path)
            if self.config.verbose:
                best = (self.history.best().value
                        if any(math.isfinite(e.value) for e in self.history.evals)
                        else float("nan"))
                for r in results:
                    print(
                        f"[tuner:{self.engine.name}] it={len(self.history):3d} "
                        f"y={r.value:.4g} best={best:.4g} "
                        f"({r.cost_seconds:.1f}s) {r.point}"
                    )
        return self.history

    def close(self) -> None:
        self.executor.close()
