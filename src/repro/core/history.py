"""Evaluation history D = {(x_i, y_i)} (paper §2.2) + persistence.

The history is the single source of truth shared by every algorithm
engine (paper Fig. 4: common data-acquisition module).  It also implements
the paper's Table-2 analysis: per-parameter sampled-range coverage.

Asynchronous evaluation support: ``mark_inflight``/``clear_inflight``
track points handed to the parallel executor but not yet measured, so
engines never re-propose them (``pending``).  Under the
completion-driven tuner loop, completions arrive out of submission
order: ``add`` appends each result the moment it lands (evaluation
``index`` is completion order, not ask order) and atomically drops the
point's in-flight mark, so the pending set and the completed set stay
disjoint at every instant.  A checkpoint written mid-stream (``save``
persists completed evaluations only) is therefore always consistent —
resuming re-evaluates whatever was still in flight, and stale in-flight
marks never leak into a checkpoint.
"""
from __future__ import annotations

import json
import math
import pathlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.observation import Observation
from repro.core.space import SearchSpace


@dataclass
class Evaluation:
    point: Dict
    value: float  # objective (throughput; higher is better)
    index: int
    cost_seconds: float = 0.0
    meta: dict = field(default_factory=dict)
    #: fraction of a full measurement this value came from (multi-fidelity
    #: tuning records partial measurements too; 1.0 = exact/full)
    fidelity: float = 1.0
    #: scheduler coordinate (ASHA rung / HyperBand global rung / PBT step)
    rung: Optional[int] = None
    #: trial ancestry (HyperBand bracket "b<idx>", PBT lineage "m<k>");
    #: resume replay routes scheduler state reconstruction by it
    lineage: Optional[str] = None


class History:
    def __init__(self, space: SearchSpace):
        self.space = space
        self.evals: List[Evaluation] = []
        self._by_key: Dict[Tuple, Evaluation] = {}
        self._inflight: set = set()
        # append-only caches behind encoded()/values()/costs(): the history
        # only ever grows, so each ask encodes just the new rows instead of
        # re-encoding the whole trace (O(n) per ask, not O(n^2) per run)
        self._enc_X = np.zeros((0, space.n_dims))
        self._enc_y = np.zeros((0,))
        self._enc_costs = np.zeros((0,))
        self._enc_fids = np.zeros((0,))
        self._enc_n = 0

    def __len__(self) -> int:
        return len(self.evals)

    def add(self, point: Dict, value: float, cost_seconds: float = 0.0,
            meta: Optional[dict] = None,
            fidelity: float = 1.0,
            rung: Optional[int] = None,
            lineage: Optional[str] = None) -> Evaluation:
        ev = Evaluation(dict(point), float(value), len(self.evals),
                        cost_seconds, meta or {}, float(fidelity),
                        rung, lineage)
        self.evals.append(ev)
        key = self.space.key(point)
        self._by_key[key] = ev
        self._inflight.discard(key)
        return ev

    def add_batch(self, points: List[Dict], values: List[float],
                  costs: Optional[List[float]] = None,
                  metas: Optional[List[dict]] = None) -> List[Evaluation]:
        """Append a completed batch (in submission order)."""
        costs = costs or [0.0] * len(points)
        metas = metas or [None] * len(points)
        return [self.add(p, v, c, m)
                for p, v, c, m in zip(points, values, costs, metas)]

    def add_observations(self, observations: List[Observation]
                         ) -> List[Evaluation]:
        """Append completed :class:`Observation` records (in order)."""
        return [self.add(o.point, o.value, o.cost_seconds, o.meta, o.fidelity,
                         o.rung, o.lineage)
                for o in observations]

    def observations(self) -> List[Observation]:
        """The trace as :class:`Observation` records — the schema
        ``Engine.tell`` takes, checkpoints snapshot, and the tuning
        service serializes over the wire."""
        return [Observation(point=dict(e.point), value=e.value,
                            cost_seconds=e.cost_seconds, fidelity=e.fidelity,
                            rung=e.rung, lineage=e.lineage,
                            meta=dict(e.meta))
                for e in self.evals]

    # -- in-flight bookkeeping (parallel executor) ---------------------------
    def mark_inflight(self, points: List[Dict]) -> None:
        for p in points:
            self._inflight.add(self.space.key(p))

    def clear_inflight(self, points: Optional[List[Dict]] = None) -> None:
        if points is None:
            self._inflight.clear()
        else:
            for p in points:
                self._inflight.discard(self.space.key(p))

    def pending(self, point: Dict) -> bool:
        """True while the point is submitted but not yet measured."""
        return self.space.key(point) in self._inflight

    def n_pending(self) -> int:
        return len(self._inflight)

    def lookup(self, point: Dict) -> Optional[Evaluation]:
        return self._by_key.get(self.space.key(point))

    def seen(self, point: Dict) -> bool:
        return self.space.key(point) in self._by_key

    def best(self, full_fidelity_only: bool = False) -> Evaluation:
        """Best finite evaluation; ``full_fidelity_only`` restricts to
        full measurements (a multi-fidelity run's partial values are
        noisy/biased by construction and should not win "best")."""
        finite = [e for e in self.evals if math.isfinite(e.value)
                  and (not full_fidelity_only or e.fidelity >= 1.0)]
        assert finite, "no finite evaluations"
        return max(finite, key=lambda e: e.value)

    def best_curve(self) -> List[float]:
        """Running best value per iteration (paper Fig. 5 curves)."""
        out, cur = [], -math.inf
        for e in self.evals:
            if math.isfinite(e.value):
                cur = max(cur, e.value)
            out.append(cur)
        return out

    def points(self) -> List[Dict]:
        return [e.point for e in self.evals]

    def _refresh_encoding_cache(self) -> None:
        """Encode only rows appended since the last call (append-only)."""
        n = len(self.evals)
        if self._enc_n == n:
            return
        cap = self._enc_X.shape[0]
        if cap < n:  # geometric growth: amortized O(1) appends
            new_cap = max(2 * cap, n, 16)
            self._enc_X = np.concatenate(
                [self._enc_X, np.zeros((new_cap - cap, self.space.n_dims))])
            self._enc_y = np.concatenate([self._enc_y, np.zeros(new_cap - cap)])
            self._enc_costs = np.concatenate(
                [self._enc_costs, np.zeros(new_cap - cap)])
            self._enc_fids = np.concatenate(
                [self._enc_fids, np.zeros(new_cap - cap)])
        for i in range(self._enc_n, n):
            e = self.evals[i]
            self._enc_X[i] = self.space.encode(e.point)
            self._enc_y[i] = e.value
            self._enc_costs[i] = e.cost_seconds
            self._enc_fids[i] = e.fidelity
        self._enc_n = n

    def values(self) -> np.ndarray:
        self._refresh_encoding_cache()
        return self._enc_y[:len(self.evals)].copy()

    def costs(self) -> np.ndarray:
        """Measured ``cost_seconds`` per evaluation (0 where unmeasured)."""
        self._refresh_encoding_cache()
        return self._enc_costs[:len(self.evals)].copy()

    def fidelities(self) -> np.ndarray:
        """Fidelity per evaluation (1.0 = full measurement)."""
        self._refresh_encoding_cache()
        return self._enc_fids[:len(self.evals)].copy()

    def encoded(self) -> Tuple[np.ndarray, np.ndarray]:
        self._refresh_encoding_cache()
        n = len(self.evals)
        return self._enc_X[:n].copy(), self._enc_y[:n].copy()

    # -- Table 2 analysis ----------------------------------------------------
    def sampled_ranges(self) -> Dict[str, Tuple]:
        """Per-parameter (min, max) of the values actually sampled."""
        out = {}
        for d in self.space.dims:
            samples = [e.point[d.name] for e in self.evals]
            if all(isinstance(v, (int, float)) for v in d.values):
                out[d.name] = (min(samples), max(samples))
            else:  # categorical: report set coverage
                out[d.name] = tuple(sorted(set(map(str, samples))))
        return out

    def sampled_range_fraction(self) -> Dict[str, float]:
        """Fraction of each tunable range covered (paper Table 2 %)."""
        out = {}
        for d in self.space.dims:
            samples = [e.point[d.name] for e in self.evals]
            vals = d.values
            if all(isinstance(v, (int, float)) for v in vals) and len(vals) > 1:
                lo, hi = min(vals), max(vals)
                out[d.name] = (max(samples) - min(samples)) / (hi - lo)
            else:
                out[d.name] = len(set(samples)) / len(vals)
        return out

    # -- persistence (tuner fault tolerance) ---------------------------------
    def to_json(self) -> str:
        return json.dumps(
            [
                {"point": e.point, "value": e.value, "index": e.index,
                 "cost_seconds": e.cost_seconds, "meta": e.meta,
                 "fidelity": e.fidelity, "rung": e.rung,
                 "lineage": e.lineage}
                for e in self.evals
            ]
        )

    def save(self, path) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(self.to_json())
        tmp.replace(p)  # atomic

    @classmethod
    def load(cls, path, space: SearchSpace) -> "History":
        h = cls(space)
        for rec in json.loads(pathlib.Path(path).read_text()):
            h.add(rec["point"], rec["value"], rec.get("cost_seconds", 0.0),
                  rec.get("meta"), rec.get("fidelity", 1.0),
                  rec.get("rung"), rec.get("lineage"))
        return h
