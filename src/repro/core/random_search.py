"""Random-search baseline (not in the paper's trio; sanity reference)."""
from __future__ import annotations

from typing import Dict

from repro.core.engine import Engine
from repro.core.history import History


class RandomSearch(Engine):
    name = "random"

    def suggest(self, history: History) -> Dict:
        return self._unseen(history, self.space.sample(self.rng, 1)[0])
