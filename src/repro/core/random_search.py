"""Random-search baseline (not in the paper's trio; sanity reference)."""
from __future__ import annotations

from typing import Dict, List

from repro.core.engine import Engine
from repro.core.history import History


class RandomSearch(Engine):
    name = "random"

    def ask(self, n: int, history: History) -> List[Dict]:
        batch: List[Dict] = []
        keys = set()
        for _ in range(n):
            p = self._unseen(history, self.space.sample(self.rng, 1)[0],
                             exclude=keys)
            keys.add(self.space.key(p))
            batch.append(p)
        return batch
