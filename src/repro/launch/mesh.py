"""Production meshes.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.  The dry-run launcher
sets XLA_FLAGS --xla_force_host_platform_device_count=512 before any jax
import; everything else sees the real device count.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         devices=jax.devices()[: int(np.prod(shape))])


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...],
              devices: Optional[list] = None):
    """Arbitrary mesh factorization (the tuner's dp/tp knob).

    shape like (dp, tp) with axes ("data", "model"), or (pods, dp, tp).
    """
    n = int(np.prod(shape))
    devices = devices if devices is not None else jax.devices()[:n]
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
