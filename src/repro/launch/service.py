"""Tuning-as-a-service: a long-lived multi-tenant tuner daemon.

One daemon owns one measurement substrate — either a remote worker
fleet (``--workers hostA:9123,hostB:9123``) or a local thread pool
measuring ``--objective module:factory()`` — and multiplexes any number
of concurrent tuning *jobs* over it:

    # the daemon (tuner host)
    PYTHONPATH=src python -m repro.launch.service --serve \
        --state-dir artifacts/service --port 9200 \
        --workers hostA:9123,hostB:9123

    # submit a job from anywhere (thin client; no jax needed)
    PYTHONPATH=src python -m repro.launch.tune --arch qwen2-0.5b \
        --algo bo --budget 50 --submit-to tunerhost:9200

    # watch / manage
    python -m repro.launch.service --connect tunerhost:9200 --list
    python -m repro.launch.service --connect tunerhost:9200 --status job-0001
    python -m repro.launch.service --connect tunerhost:9200 --cancel job-0001

Clients speak protocol v2 of the length-prefixed-JSON protocol
(``repro.tuning.protocol``): ``submit_job`` / ``job_status`` /
``list_jobs`` / ``cancel_job``.  Submissions are validated at the front
door — ``TunerConfig.from_dict`` and ``JobSpec.from_dict`` raise
``ValueError`` naming any unknown key, and the error text comes back in
the reply instead of a silently mis-configured job.

Fair-share scheduling
---------------------

Every job runs a real :class:`~repro.core.Tuner` on its own thread, but
all jobs share ONE pool (the ``RemoteWorkerPool`` over the fleet, or
one thread pool locally): per-job executors are built around the shared
pool (``EvaluationExecutor(pool=...)``) so no job can monopolize the
slots.  A governor divides the slot total across runnable jobs —
``slots // n`` each, remainder rotated round-robin — by setting each
executor's ``slot_cap``; a tuner's completion-driven loop sizes its
in-flight window to ``executor.parallelism``, so the cap takes effect
at the next completion without revoking dispatched work.

Crash safety
------------

Every job checkpoints continuously under ``<state_dir>/jobs/<job_id>/``:
the tuner's history after *every* recorded evaluation (atomic
tmp+rename, via the standard ``checkpoint_path`` machinery) and the job
document (spec + state) through
:class:`~repro.checkpoint.checkpointer.JsonCheckpointer` (sha256
integrity, keep-last-k).  A SIGKILL'd daemon restarted on the same
``--state-dir`` reloads every job, resumes the unfinished ones from
their checkpoints (``Tuner._resume`` replays the history into the
engine; the multi-fidelity loop replays rung state and budget spend),
and loses only measurements that were in flight at the kill — nothing
recorded is lost, nothing is double-recorded (the CI ``service-smoke``
job gates exactly this).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import socket
import threading
import time
import traceback
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional

from repro.tuning import protocol as proto
from repro.tuning.protocol import (JobSpec, PROTOCOL_V2, parse_address,
                                   recv_msg, send_msg)

TERMINAL_STATES = ("done", "failed", "cancelled")


class _Job:
    """One tuning job: spec + lifecycle + its Tuner (while running)."""

    __slots__ = ("job_id", "spec", "state", "error", "tuner", "thread",
                 "dir", "ckpt", "submitted_at", "finished_at")

    def __init__(self, job_id: str, spec: JobSpec, job_dir: pathlib.Path,
                 ckpt) -> None:
        self.job_id = job_id
        self.spec = spec
        self.state = "pending"  # pending -> running -> done|failed|cancelled
        self.error: Optional[str] = None
        self.tuner = None
        self.thread: Optional[threading.Thread] = None
        self.dir = job_dir
        self.ckpt = ckpt  # JsonCheckpointer over dir/snaps
        self.submitted_at = time.time()
        self.finished_at: Optional[float] = None

    def doc(self) -> dict:
        """The checkpointed job document (what a restart reloads)."""
        return {"job_id": self.job_id, "spec": self.spec.to_dict(),
                "state": self.state, "error": self.error,
                "submitted_at": self.submitted_at,
                "finished_at": self.finished_at}


class TuningService:
    """The daemon: accepts protocol-v2 clients, runs jobs over one pool.

    ``workers`` selects the remote fleet (jobs share one
    ``RemoteWorkerPool``; measurement objectives live on the workers);
    otherwise ``objective`` (an evaluator, callable, or
    ``module:factory()`` spec string) is measured locally on a shared
    ``parallelism``-wide thread pool.  Jobs may also carry their own
    ``objective`` spec (local mode only), resolved — and validated —
    at submission.
    """

    def __init__(self, state_dir, *, objective=None,
                 workers: Optional[List[str]] = None, parallelism: int = 4,
                 host: str = "127.0.0.1", port: int = 0,
                 eval_timeout: Optional[float] = None, verbose: bool = True,
                 rebalance_s: float = 0.5, corpus_path=None,
                 heartbeat_s: Optional[float] = None,
                 fleet_port: Optional[int] = 0,
                 fleet_homogeneity: str = "strict"):
        from repro.checkpoint.checkpointer import JsonCheckpointer

        self._JsonCheckpointer = JsonCheckpointer
        self.state_dir = pathlib.Path(state_dir)
        self.jobs_dir = self.state_dir / "jobs"
        self.jobs_dir.mkdir(parents=True, exist_ok=True)
        # transfer-learning observation corpus: every job's completed
        # evaluations are recorded here, and later jobs on neighboring
        # workloads warm-start from it (default: <state_dir>/corpus.json)
        self.corpus_path = (pathlib.Path(corpus_path) if corpus_path
                            else self.state_dir / "corpus.json")
        self.verbose = verbose
        self.eval_timeout = eval_timeout
        self._lock = threading.RLock()
        self._jobs: Dict[str, _Job] = {}
        self._seq = 0
        self._rr = 0  # round-robin offset for the remainder slots
        self._stopping = threading.Event()
        self._objectives: Dict[str, object] = {}  # spec string -> evaluator

        # -- the one shared measurement substrate -----------------------------
        self.workers = list(workers) if workers else None
        if self.workers:
            from repro.tuning.remote import FleetOptions, RemoteWorkerPool

            self._pool = RemoteWorkerPool(
                self.workers, eval_timeout=eval_timeout,
                fleet=FleetOptions(listen_port=fleet_port,
                                   homogeneity=fleet_homogeneity,
                                   heartbeat_s=heartbeat_s))
            self._backend = "remote"
            self._local_slots = None
        else:
            self._local_slots = max(1, int(parallelism))
            self._pool = ThreadPoolExecutor(max_workers=self._local_slots,
                                            thread_name_prefix="svc-measure")
            self._backend = "thread"
        self._default_objective = self._resolve(objective)
        if self._backend == "thread" and self._default_objective is None:
            # jobs may still carry their own objective specs; without any
            # objective at all the daemon can only reject submissions
            self._log("no --objective: local jobs must carry their own "
                      "objective spec")

        # -- client listener ---------------------------------------------------
        self._lsock = socket.create_server((host, int(port)))
        self.host, self.port = self._lsock.getsockname()[:2]
        self._threads: List[threading.Thread] = []

        # restart-recovery BEFORE accepting clients: a status probe that
        # races the rescan must not see an empty daemon
        self._recover()

        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="svc-accept")
        self._governor_thread = threading.Thread(
            target=self._governor_loop, args=(max(0.05, rebalance_s),),
            daemon=True, name="svc-governor")

    # -- lifecycle ------------------------------------------------------------
    @property
    def address(self) -> str:
        return f"{self.host}:{self.port}"

    def start(self) -> "TuningService":
        self._accept_thread.start()
        self._governor_thread.start()
        self._log(f"serving on {self.address} "
                  f"(backend={self._backend}, slots={self.total_slots()})")
        return self

    def serve_forever(self) -> None:
        self.start()
        try:
            while not self._stopping.wait(0.5):
                pass
        except KeyboardInterrupt:
            self._log("interrupted; shutting down")
        finally:
            self.stop()

    def stop(self) -> None:
        """Graceful shutdown: stop jobs at their next completion, close
        the listener, shut the shared pool down."""
        if self._stopping.is_set():
            return
        self._stopping.set()
        try:
            self._lsock.close()
        except OSError:
            pass
        with self._lock:
            running = [j for j in self._jobs.values() if j.tuner is not None]
        for j in running:
            j.tuner.request_stop()
        for j in running:
            if j.thread is not None:
                j.thread.join(timeout=10.0)
        self._pool.shutdown(wait=False, cancel_futures=True)

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(f"[service] {msg}", flush=True)

    # -- capacity / fair share -------------------------------------------------
    def total_slots(self) -> int:
        if self._backend == "remote":
            return max(1, self._pool.parallelism)
        return self._local_slots

    def _rebalance(self, rotate: bool = False) -> None:
        """Divide the slot total across runnable jobs: ``slots // n``
        each (min 1), remainder to the next ``slots % n`` jobs in
        round-robin order.  Applied via ``executor.slot_cap`` — the
        tuner loops shrink/grow their in-flight window at the next
        completion, so no dispatched measurement is ever revoked."""
        with self._lock:
            runnable = [j for j in self._jobs.values()
                        if j.state == "running" and j.tuner is not None]
            n = len(runnable)
            if n == 0:
                return
            runnable.sort(key=lambda j: j.job_id)
            total = self.total_slots()
            share, rem = divmod(total, n)
            if rotate:
                self._rr = (self._rr + 1) % n
            for i, job in enumerate(runnable):
                bonus = 1 if (i - self._rr) % n < rem else 0
                job.tuner.executor.slot_cap = max(1, share + bonus)

    def _governor_loop(self, interval: float) -> None:
        while not self._stopping.wait(interval):
            self._rebalance(rotate=True)

    # -- objective resolution --------------------------------------------------
    def _resolve(self, objective):
        """Evaluator | callable | ``module:factory()`` spec | None."""
        if objective is None or not isinstance(objective, str):
            return objective
        if objective not in self._objectives:
            from repro.launch.worker import resolve_objective

            self._objectives[objective] = resolve_objective(objective)
        return self._objectives[objective]

    # -- job lifecycle ---------------------------------------------------------
    def submit(self, spec: JobSpec, job_id: Optional[str] = None) -> str:
        """Validate + persist + launch one job; returns its id.

        Raises ``ValueError`` (bad space/config/objective) so the
        protocol layer can return the precise reason."""
        from repro.core import SearchSpace, TunerConfig

        SearchSpace.from_dicts(spec.space)  # validate, loudly
        TunerConfig.from_dict(spec.config)  # unknown keys raise here
        if spec.objective is not None:
            if self._backend == "remote":
                raise ValueError(
                    "per-job objectives are a local-measurement feature; "
                    "this daemon drives a remote fleet whose workers own "
                    "their objectives")
            try:
                self._resolve(spec.objective)
            except Exception as e:
                raise ValueError(
                    f"objective spec {spec.objective!r} failed to "
                    f"resolve: {e!r}") from None
        elif self._backend == "thread" and self._default_objective is None:
            raise ValueError(
                "this daemon has no --objective and measures locally; "
                "the job must carry an objective spec")
        with self._lock:
            if job_id is None:
                self._seq += 1
                while f"job-{self._seq:04d}" in self._jobs:
                    self._seq += 1
                job_id = f"job-{self._seq:04d}"
            elif job_id in self._jobs:
                raise ValueError(f"job id {job_id!r} already exists")
            job_dir = self.jobs_dir / job_id
            job = _Job(job_id, spec, job_dir,
                       self._JsonCheckpointer(job_dir / "snaps"))
            self._jobs[job_id] = job
        job.ckpt.save(job.doc())
        self._launch(job)
        return job_id

    def _launch(self, job: _Job) -> None:
        job.thread = threading.Thread(target=self._run_job, args=(job,),
                                      daemon=True, name=f"svc-{job.job_id}")
        job.thread.start()

    def _run_job(self, job: _Job) -> None:
        from repro.core import SearchSpace, TransferConfig, Tuner, TunerConfig
        from repro.tuning.executor import EvaluationExecutor

        try:
            space = SearchSpace.from_dicts(job.spec.space)
            cfg = TunerConfig.from_dict(job.spec.config)
            # the daemon owns placement: jobs always checkpoint into
            # their state dir (crash-resume), never spawn their own
            # fleets, and log through the service
            cfg.checkpoint_path = str(job.dir / "history.json")
            cfg.verbose = False
            cfg.executor.workers = None
            cfg.executor.backend = self._backend
            # every job records into (and may warm-start from) the
            # daemon's shared observation corpus, unless the submitter
            # pointed the job at a corpus of its own
            if self.corpus_path is not None and not cfg.transfer:
                cfg.transfer = TransferConfig(
                    corpus_path=str(self.corpus_path))
            if cfg.transfer and not cfg.transfer.job_id:
                cfg.transfer.job_id = job.job_id
            objective = (self._resolve(job.spec.objective)
                         or self._default_objective
                         or _remote_standin)
            timeout = (cfg.executor.eval_timeout
                       if cfg.executor.eval_timeout is not None
                       else self.eval_timeout)
            executor = EvaluationExecutor(
                objective, space, backend=self._backend, pool=self._pool,
                timeout=timeout, cache_path=cfg.executor.memo_cache_path,
                parallelism=self.total_slots())
            tuner = Tuner(objective, space, cfg, executor=executor)
            resumed = len(tuner.history)
            with self._lock:
                job.tuner = tuner
                job.state = "running"
            job.ckpt.save(job.doc())
            self._rebalance()
            self._log(f"{job.job_id} running "
                      f"(algo={cfg.algorithm}, budget={cfg.budget}"
                      + (f", resumed {resumed} evals" if resumed else "")
                      + ")")
            tuner.run()
            with self._lock:
                if not tuner.stop_requested:
                    job.state = "done"
                elif self._stopping.is_set():
                    # daemon shutdown, not a user cancel: stay
                    # non-terminal so a restart resumes this job from
                    # its checkpoint
                    job.state = "running"
                else:
                    job.state = "cancelled"
                job.finished_at = (time.time()
                                   if job.state != "running" else None)
        except Exception as e:
            with self._lock:
                job.state = "failed"
                job.error = f"{e!r}"
                job.finished_at = time.time()
            self._log(f"{job.job_id} failed: {e!r}\n"
                      + traceback.format_exc())
        finally:
            with self._lock:
                tuner, job.tuner = job.tuner, None
            if tuner is not None:
                tuner.executor.cache.flush()
                corpus = getattr(tuner.executor, "corpus", None)
                if corpus is not None:
                    corpus.flush()
            job.ckpt.save(job.doc())
            self._rebalance()
            self._log(f"{job.job_id} -> {job.state} "
                      f"({self._n_evals(job)} evals recorded)")

    def _n_evals(self, job: _Job) -> int:
        with self._lock:
            if job.tuner is not None:
                return len(job.tuner.history)
        hist = job.dir / "history.json"
        if hist.exists():
            try:
                return len(json.loads(hist.read_text()))
            except (OSError, ValueError):
                return 0
        return 0

    def cancel(self, job_id: str) -> bool:
        """Stop a job at its next completion; True if it was running."""
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None:
                raise KeyError(job_id)
            if job.tuner is not None:
                job.tuner.request_stop()
                return True
            if job.state not in TERMINAL_STATES:
                job.state = "cancelled"
                job.finished_at = time.time()
                job.ckpt.save(job.doc())
            return False

    # -- restart recovery ------------------------------------------------------
    def _recover(self) -> None:
        """Reload every job document; relaunch the unfinished ones.

        A job killed mid-run resumes from its history checkpoint: the
        tuner replays recorded evaluations into the engine (and the
        multi-fidelity loop replays rung state + budget spend), so only
        measurements in flight at the crash are re-measured."""
        for job_dir in sorted(self.jobs_dir.iterdir()
                              if self.jobs_dir.exists() else []):
            if not job_dir.is_dir():
                continue
            ckpt = self._JsonCheckpointer(job_dir / "snaps")
            doc = ckpt.load()
            if doc is None:
                self._log(f"skipping {job_dir.name}: no readable snapshot")
                continue
            try:
                spec = JobSpec.from_dict(doc["spec"])
            except (KeyError, ValueError) as e:
                self._log(f"skipping {job_dir.name}: bad snapshot ({e!r})")
                continue
            job = _Job(doc.get("job_id", job_dir.name), spec, job_dir, ckpt)
            job.state = doc.get("state", "pending")
            job.error = doc.get("error")
            job.submitted_at = doc.get("submitted_at", job.submitted_at)
            job.finished_at = doc.get("finished_at")
            with self._lock:
                self._jobs[job.job_id] = job
                tail = job.job_id.rsplit("-", 1)[-1]
                if tail.isdigit():
                    self._seq = max(self._seq, int(tail))
            if job.state in TERMINAL_STATES:
                continue
            job.state = "pending"
            self._log(f"recovering {job.job_id} "
                      f"(checkpoint: {job_dir / 'history.json'})")
            self._launch(job)

    # -- status ----------------------------------------------------------------
    def fleet_health(self) -> dict:
        if self._backend == "remote":
            out = {"backend": "remote", "slots": self.total_slots(),
                   "workers": self._pool.fleet_health()}
            out.update(self._pool.fleet_stats())
            return out
        return {"backend": "thread", "slots": self.total_slots()}

    def job_status(self, job_id: str) -> dict:
        import math

        with self._lock:
            job = self._jobs.get(job_id)
            if job is None:
                raise KeyError(job_id)
            tuner = job.tuner
            out = {"type": "status", "job_id": job.job_id,
                   "name": job.spec.name, "state": job.state,
                   "error": job.error, "submitted_at": job.submitted_at,
                   "finished_at": job.finished_at,
                   "fleet": self.fleet_health()}
        if tuner is not None:
            hist = tuner.history
            out["n_evals"] = len(hist)
            out["slot_cap"] = tuner.executor.slot_cap
            curve = hist.best_curve()
            out["best_curve"] = curve
            if curve and math.isfinite(curve[-1]):
                best = hist.best()
                out["best"] = {"value": best.value, "point": best.point}
            sched = tuner.rung_scheduler
            if sched is not None:
                # "rungs" predates the scheduler zoo: rung-shaped rows
                # for whichever ladder scheduler is driving (old clients
                # render them as before); the full picture — kind,
                # per-bracket tables, PBT population — rides "scheduler"
                stats = sched.stats()
                if all("rung" in row for row in stats):
                    out["rungs"] = stats
                out["scheduler"] = {"kind": getattr(sched, "kind", "asha"),
                                    "stats": stats,
                                    "snapshot": sched.snapshot()}
        else:
            hist = job.dir / "history.json"
            evals = []
            if hist.exists():
                try:
                    evals = json.loads(hist.read_text())
                except (OSError, ValueError):
                    evals = []
            out["n_evals"] = len(evals)
            curve, cur = [], -math.inf
            best = None
            for e in evals:
                v = e.get("value", -math.inf)
                if isinstance(v, (int, float)) and math.isfinite(v) \
                        and v > cur:
                    cur, best = v, e
                curve.append(cur)
            out["best_curve"] = curve
            if best is not None:
                out["best"] = {"value": best["value"], "point": best["point"]}
        return out

    def list_jobs(self) -> List[dict]:
        with self._lock:
            jobs = sorted(self._jobs.values(), key=lambda j: j.job_id)
            return [{"job_id": j.job_id, "name": j.spec.name,
                     "state": j.state, "n_evals": self._n_evals(j),
                     "error": j.error} for j in jobs]

    # -- protocol server -------------------------------------------------------
    def _accept_loop(self) -> None:
        self._lsock.settimeout(0.5)
        while not self._stopping.is_set():
            try:
                conn, _peer = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            t = threading.Thread(target=self._client_session, args=(conn,),
                                 daemon=True, name="svc-client")
            t.start()
            self._threads.append(t)

    def _client_session(self, conn: socket.socket) -> None:
        try:
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn.settimeout(10.0)  # handshake; requests may then idle
            hello = recv_msg(conn)
            version = proto.negotiate(hello)
            if version is None or version < PROTOCOL_V2:
                send_msg(conn, {"type": "error",
                                "error": f"tuning service needs protocol "
                                         f">= {PROTOCOL_V2}, hello was "
                                         f"{hello!r}"})
                return
            send_msg(conn, {"type": "welcome", "protocol": version,
                            "service": "repro-tuning",
                            "slots": self.total_slots()})
            conn.settimeout(None)
            while True:
                msg = recv_msg(conn)
                reply = self._dispatch(msg)
                if reply is None:  # bye
                    return
                send_msg(conn, reply)
        except (ConnectionError, OSError, ValueError):
            pass  # client went away / spoke garbage: that session is over
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def _dispatch(self, msg: dict) -> Optional[dict]:
        kind = msg.get("type")
        try:
            if kind == "submit_job":
                spec = JobSpec.from_dict(msg.get("job") or {})
                job_id = self.submit(spec)
                return {"type": "job_accepted", "job_id": job_id}
            if kind == "job_status":
                return self.job_status(msg.get("job_id", ""))
            if kind == "list_jobs":
                return {"type": "jobs", "jobs": self.list_jobs()}
            if kind == "cancel_job":
                was_running = self.cancel(msg.get("job_id", ""))
                return {"type": "cancelled", "job_id": msg.get("job_id"),
                        "was_running": was_running}
            if kind == "bye":
                return None
            return {"type": "error", "error": f"unknown request {kind!r}"}
        except KeyError as e:
            return {"type": "error", "error": f"no such job: {e.args[0]!r}"}
        except ValueError as e:
            return {"type": "error", "error": str(e)}
        except Exception as e:  # never let one request kill the session
            return {"type": "error", "error": f"internal error: {e!r}"}


def _remote_standin(point):
    """Executor-side objective placeholder for remote-fleet daemons:
    measurements run on the workers, so this is only ever called if the
    executor's inline fallback paths fire — which the remote backend
    routes back to the fleet instead."""
    raise RuntimeError(
        "this daemon measures on its remote worker fleet; no local "
        "objective is available")


# ---------------------------------------------------------------------------
# thin client
# ---------------------------------------------------------------------------

class ServiceClient:
    """Blocking request/response client for the service protocol."""

    def __init__(self, address: str, connect_timeout: float = 10.0):
        host, port = parse_address(address)
        self.address = address
        self._sock = socket.create_connection((host, port),
                                              timeout=connect_timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        send_msg(self._sock, proto.hello())
        welcome = recv_msg(self._sock)
        if welcome.get("type") != "welcome":
            self._sock.close()
            raise ConnectionError(
                f"{address} is not a tuning service: {welcome!r}")
        self.protocol = welcome.get("protocol")
        self.slots = welcome.get("slots")
        self._sock.settimeout(None)
        self._lock = threading.Lock()

    def _rpc(self, msg: dict) -> dict:
        with self._lock:
            send_msg(self._sock, msg)
            reply = recv_msg(self._sock)
        if reply.get("type") == "error":
            raise RuntimeError(f"service error: {reply.get('error')}")
        return reply

    def submit(self, spec: JobSpec) -> str:
        return self._rpc({"type": "submit_job",
                          "job": spec.to_dict()})["job_id"]

    def status(self, job_id: str) -> dict:
        return self._rpc({"type": "job_status", "job_id": job_id})

    def list_jobs(self) -> List[dict]:
        return self._rpc({"type": "list_jobs"})["jobs"]

    def cancel(self, job_id: str) -> dict:
        return self._rpc({"type": "cancel_job", "job_id": job_id})

    def wait(self, job_id: str, timeout: Optional[float] = None,
             poll_s: float = 0.2, on_status=None) -> dict:
        """Poll until the job reaches a terminal state; returns the final
        status.  ``on_status`` (if given) sees every polled snapshot —
        the CLI progress reporter hook."""
        deadline = time.time() + timeout if timeout is not None else None
        while True:
            st = self.status(job_id)
            if on_status is not None:
                on_status(st)
            if st.get("state") in TERMINAL_STATES:
                return st
            if deadline is not None and time.time() >= deadline:
                raise TimeoutError(
                    f"job {job_id} still {st.get('state')!r} after "
                    f"{timeout}s")
            time.sleep(poll_s)

    def close(self) -> None:
        try:
            send_msg(self._sock, {"type": "bye"})
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "ServiceClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def print_status(st: dict) -> None:
    """Render one job_status reply for humans (the CLI reporter)."""
    best = st.get("best")
    curve = st.get("best_curve") or []
    line = (f"[{st['job_id']}] {st['state']:9s} evals={st.get('n_evals', 0)}"
            + (f" best={best['value']:.6g}" if best else " best=n/a"))
    if st.get("slot_cap") is not None:
        line += f" slots<={st['slot_cap']}"
    print(line)
    if curve:
        tail = ", ".join(f"{v:.4g}" for v in curve[-8:])
        print(f"    best-so-far: ...{tail}" if len(curve) > 8
              else f"    best-so-far: {tail}")
    sched = st.get("scheduler") or {}
    snap = sched.get("snapshot") or {}
    if sched.get("kind") == "hyperband" and snap.get("brackets"):
        for b in snap["brackets"]:
            print(f"    bracket {b['bracket']} "
                  f"(min_f={b['min_fidelity']}, spend={b['spend']:.4g}):")
            for row in b.get("rungs") or []:
                print(f"      rung {row['rung']} (f={row['fidelity']}): "
                      f"started={row['started']} "
                      f"completed={row['completed']} "
                      f"promoted={row['promoted']} "
                      f"preempted={row['preempted']}")
    elif sched.get("kind") == "pbt" and snap:
        row = (sched.get("stats") or [{}])[0]
        best = row.get("best")
        median = row.get("median")
        print(f"    population {len(snap.get('members') or [])}"
              f"/{snap.get('population')}: "
              + (f"best={best:.6g} " if best is not None else "best=n/a ")
              + (f"median={median:.6g} " if median is not None
                 else "median=n/a ")
              + f"steps={snap.get('steps')} forks={snap.get('forks')} "
                f"preempted={snap.get('preempted')}")
    else:
        for row in st.get("rungs") or []:
            print(f"    rung {row['rung']} (f={row['fidelity']}): "
                  f"started={row['started']} completed={row['completed']} "
                  f"promoted={row['promoted']} preempted={row['preempted']}")
    fleet = st.get("fleet") or {}
    if fleet.get("backend") == "remote":
        workers = fleet.get("workers", [])
        alive = sum(1 for w in workers if w.get("alive"))
        line = (f"    fleet: {alive}/{len(workers)} workers alive, "
                f"{fleet.get('slots')} slots")
        if fleet.get("join_address"):
            line += f", join={fleet['join_address']}"
        print(line)
        spec = fleet.get("speculating", 0)
        ages = [w.get("inflight_age_max") for w in workers
                if w.get("inflight_age_max") is not None]
        if spec or ages:
            line = f"    stragglers: speculating={spec}"
            if ages:
                line += f" inflight_age_max={max(ages):.1f}s"
            wins, losses = (fleet.get("speculation_wins", 0),
                            fleet.get("losers_discarded", 0))
            if wins or losses:
                line += f" (wins={wins} losers_discarded={losses})"
            print(line)
    if st.get("error"):
        print(f"    error: {st['error']}")


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Multi-tenant tuning service (daemon + management "
                    "client).  See repro.tuning.protocol for the wire "
                    "format.")
    ap.add_argument("--serve", action="store_true",
                    help="run the daemon (otherwise: management client, "
                         "needs --connect)")
    ap.add_argument("--state-dir", default="artifacts/service",
                    help="daemon: where job checkpoints live; restarting "
                         "on the same dir resumes unfinished jobs")
    ap.add_argument("--host", default="0.0.0.0",
                    help="daemon: interface to listen on")
    ap.add_argument("--port", type=int, default=9200,
                    help="daemon: port (0 = ephemeral, printed)")
    ap.add_argument("--workers", default=None,
                    help="daemon: comma-separated host:port measurement "
                         "workers; jobs share this one fleet")
    ap.add_argument("--objective", default=None,
                    help="daemon (local measurement): module:attr objective "
                         "spec, () suffix calls a zero-arg factory")
    ap.add_argument("--parallelism", type=int, default=4,
                    help="daemon (local measurement): shared thread-pool "
                         "width")
    ap.add_argument("--eval-timeout", type=float, default=None,
                    help="daemon: default seconds per measurement")
    ap.add_argument("--heartbeat-s", type=float, default=None,
                    help="daemon (remote fleet): fallback heartbeat "
                         "interval; each worker's stall window is 3 missed "
                         "beats of its own registered value")
    ap.add_argument("--fleet-port", type=int, default=0,
                    help="daemon (remote fleet): join socket kept open for "
                         "the daemon's lifetime so workers can register "
                         "mid-run (0 = ephemeral, printed in --status)")
    ap.add_argument("--fleet-homogeneity", default="strict",
                    choices=["strict", "normalize"],
                    help="daemon (remote fleet): refuse mixed hardware "
                         "fingerprints (strict, default) or admit and "
                         "cost-calibrate them (normalize)")
    ap.add_argument("--corpus", default=None,
                    help="daemon: transfer-learning observation corpus "
                         "shared by all jobs (default: "
                         "<state-dir>/corpus.json); jobs record every "
                         "completed evaluation here and warm-start from "
                         "neighboring workloads")
    ap.add_argument("--quiet", action="store_true",
                    help="daemon: suppress progress logging")
    ap.add_argument("--connect", default=None,
                    help="client: service host:port")
    ap.add_argument("--list", action="store_true",
                    help="client: list jobs")
    ap.add_argument("--status", default=None, metavar="JOB_ID",
                    help="client: show one job's progress")
    ap.add_argument("--watch", action="store_true",
                    help="client (with --status): poll until terminal")
    ap.add_argument("--cancel", default=None, metavar="JOB_ID",
                    help="client: cancel a job")
    args = ap.parse_args(argv)

    if args.serve:
        workers = ([w.strip() for w in args.workers.split(",") if w.strip()]
                   if args.workers else None)
        service = TuningService(
            args.state_dir, objective=args.objective, workers=workers,
            parallelism=args.parallelism, host=args.host, port=args.port,
            eval_timeout=args.eval_timeout, verbose=not args.quiet,
            corpus_path=args.corpus, heartbeat_s=args.heartbeat_s,
            fleet_port=args.fleet_port,
            fleet_homogeneity=args.fleet_homogeneity)
        service.serve_forever()
        return service

    if not args.connect:
        ap.error("either --serve (daemon) or --connect host:port (client)")
    with ServiceClient(args.connect) as client:
        if args.list or not (args.status or args.cancel):
            rows = client.list_jobs()
            if not rows:
                print("no jobs")
            for r in rows:
                line = (f"{r['job_id']}  {r['state']:9s} "
                        f"evals={r['n_evals']}")
                if r.get("name"):
                    line += f"  ({r['name']})"
                if r.get("error"):
                    line += f"  error: {r['error']}"
                print(line)
        if args.status:
            if args.watch:
                client.wait(args.status, on_status=print_status, poll_s=1.0)
            else:
                print_status(client.status(args.status))
        if args.cancel:
            reply = client.cancel(args.cancel)
            print(f"{args.cancel}: cancel "
                  f"{'delivered' if reply.get('was_running') else 'noted'}")
    return None


if __name__ == "__main__":
    main()
