"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
        --steps 200 --batch 8 --seq 128

``--reduced`` trains the tiny same-family config on the local device(s)
(the CPU-runnable path used by examples/tests); without it the full config
is used (real-hardware path).  The fault-tolerance machinery (checkpoint /
restart / straggler detection) is active either way; ``--inject-failure``
demonstrates recovery.
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models.runtime import Runtime
from repro.optim.optimizer import OptimizerConfig
from repro.runtime.fault_tolerance import FailureInjector
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="simulate a worker failure at this step")
    ap.add_argument("--d-model", type=int, default=None,
                    help="override reduced width (e.g. for the ~100M example)")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--tuning-db", default=None, metavar="PATH",
                    help="persisted TuningDB (benchmarks/kernel_sweep.py "
                         "output); tuned kernel tiles are picked up at "
                         "trace time")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.d_model:
        cfg = dataclasses.replace(
            cfg, d_model=args.d_model, head_dim=args.d_model // cfg.num_heads,
            d_ff=4 * args.d_model,
        )
    if args.layers:
        period = cfg.layer_period()
        cfg = dataclasses.replace(cfg, num_layers=max(period, args.layers // period * period))

    opt_cfg = OptimizerConfig(learning_rate=args.lr, warmup_steps=20,
                              total_steps=args.steps)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    tcfg = TrainerConfig(steps=args.steps, microbatches=args.microbatches,
                         checkpoint_dir=args.checkpoint_dir,
                         checkpoint_every=args.checkpoint_every)
    injector = (FailureInjector(at_steps=[args.inject_failure])
                if args.inject_failure is not None else None)
    rt = Runtime(compute_dtype="f32")
    if args.tuning_db:
        from repro.tuning.tundb import TuningDB
        rt = dataclasses.replace(rt, tuning_db=TuningDB(args.tuning_db))
    trainer = Trainer(cfg, opt_cfg, data_cfg, tcfg,
                      rt=rt,
                      failure_injector=injector)
    log = trainer.run()
    first, last = log[0]["loss"], log[-1]["loss"]
    print(f"[train] done: loss {first:.4f} -> {last:.4f} "
          f"({len(log)} logged steps); events: {trainer.events or 'none'}")
    return log


if __name__ == "__main__":
    main()
