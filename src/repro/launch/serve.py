"""Serving driver: batched prefill + decode with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --requests 16 --prompt-len 64 --gen-len 32 --batch 8

Requests arrive with ragged prompt lengths; the scheduler packs them into
fixed decode batches, prefills, then decodes until every request has
``gen_len`` tokens, refilling slots as requests finish.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.models.params import split_params
from repro.models.runtime import Runtime
from repro.serve.serve_step import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tuning-db", default=None, metavar="PATH",
                    help="persisted TuningDB (benchmarks/kernel_sweep.py "
                         "output); tuned kernel tiles are picked up at "
                         "trace time")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    rt = Runtime(compute_dtype="f32")
    if args.tuning_db:
        from repro.tuning.tundb import TuningDB
        rt = dataclasses.replace(rt, tuning_db=TuningDB(args.tuning_db))
    params, _ = split_params(model.init(jax.random.PRNGKey(0)))

    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=rng.integers(args.prompt_len // 2,
                                                          args.prompt_len + 1))
        for _ in range(args.requests)
    ]

    prefill = jax.jit(make_prefill_step(model, rt))
    decode = jax.jit(make_decode_step(model, rt), donate_argnums=(2,))
    cache_len = args.prompt_len + args.gen_len

    done, t0, tokens_out = [], time.perf_counter(), 0
    queue = list(enumerate(prompts))
    while queue:
        wave = queue[: args.batch]
        queue = queue[args.batch:]
        B = args.batch
        toks = np.zeros((B, args.prompt_len), np.int32)
        for i, (_, p) in enumerate(wave):  # left-pad to a packed batch
            toks[i, args.prompt_len - len(p):] = p
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (B, cfg.num_frontend_tokens, cfg.d_model), jnp.float32)
        if cfg.encoder_layers:
            batch["encoder_embeds"] = jnp.zeros(
                (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
        cache, _ = split_params(model.init_cache(B, cache_len))
        logits, cache = prefill(params, batch, cache)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        outs = [tok]
        for _ in range(args.gen_len - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            outs.append(tok)
        gen = jnp.concatenate(outs, axis=1)
        jax.block_until_ready(gen)
        tokens_out += int(gen.size)
        for i, (rid, _) in enumerate(wave):
            done.append((rid, np.asarray(gen[i])))

    dt = time.perf_counter() - t0
    print(f"[serve] {len(done)} requests, {tokens_out} tokens in {dt:.2f}s "
          f"=> {tokens_out/dt:.1f} tok/s (greedy, batch={args.batch})")
    return done


if __name__ == "__main__":
    main()
