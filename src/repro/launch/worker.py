"""Measurement-worker daemon for the remote executor backend.

Run one of these per measurement host, point it at the objective it
should serve, and hand the tuner the ``host:port`` list:

    # on each measurement host
    PYTHONPATH=src python -m repro.launch.worker --port 9123 --slots 2 \
        --objective benchmarks.perf_iterations:make_remote_bench_objective()

    # on the tuner host
    PYTHONPATH=src python -m repro.launch.tune --arch qwen2-0.5b \
        --backend remote --workers hostA:9123,hostB:9123 ...

(For the roofline objective specifically, ``launch/tune.py
--serve-worker`` is the turnkey spelling: it builds the same
``RooflineEvaluator`` the driver would and serves it, so both ends are
guaranteed to agree on the objective.)

``--objective module:attr`` names the objective; append ``()`` to call
it as a zero-argument factory (the usual shape — a factory builds the
evaluator *on the worker*, so heavyweight state like compile caches
never crosses the wire).  The resolved object may be an
``Evaluator``/``(value, meta)`` callable or a plain scalar objective;
``as_evaluator`` normalizes it exactly as the local backends do.

The daemon registers with the connecting tuner, heartbeats every
``--heartbeat`` seconds, pulls ``(point, fidelity)`` tasks into a
``--slots``-wide measurement pool, and streams results back in
completion order.  It never touches the memo cache — results are
persisted by the tuner host, so workers need no shared filesystem.  A
tuner disconnect ends the session and the daemon goes back to
accepting, so a fleet survives tuner restarts.
"""
from __future__ import annotations

import argparse
import importlib
import os

from repro.tuning.remote import DEFAULT_HEARTBEAT_S, WorkerServer


def resolve_objective(spec: str):
    """``module:attr`` or ``module:factory()`` -> the objective object."""
    mod_name, sep, attr = spec.partition(":")
    if not sep or not attr:
        raise ValueError(
            f"objective spec {spec!r} is not module:attr (append () to "
            "call a zero-arg factory, e.g. pkg.mod:make_objective())")
    call = attr.endswith("()")
    if call:
        attr = attr[:-2]
    obj = getattr(importlib.import_module(mod_name), attr)
    return obj() if call else obj


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Serve measurements to a remote-backend tuner "
                    "(see repro.tuning.remote for the wire protocol).")
    ap.add_argument("--objective", required=True,
                    help="module:attr naming the objective to serve; "
                         "append () to call it as a zero-arg factory")
    ap.add_argument("--host", default="0.0.0.0",
                    help="interface to listen on (default: all)")
    ap.add_argument("--port", type=int, default=9123,
                    help="port to listen on (0 = ephemeral, printed)")
    ap.add_argument("--slots", type=int, default=1,
                    help="concurrent measurements this host runs "
                         "(fleet parallelism = sum of slots)")
    ap.add_argument("--heartbeat", type=float, default=DEFAULT_HEARTBEAT_S,
                    help="seconds between heartbeats (the tuner declares "
                         "this worker dead after 3 missed ones)")
    args = ap.parse_args(argv)

    server = WorkerServer(resolve_objective(args.objective),
                          host=args.host, port=args.port,
                          slots=args.slots, heartbeat_s=args.heartbeat)
    print(f"[worker] pid={os.getpid()} serving {args.objective!r} on "
          f"{server.host}:{server.port} (slots={server.slots})", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        print("[worker] interrupted; shutting down")
    return server


if __name__ == "__main__":
    main()
