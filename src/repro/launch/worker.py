"""Measurement-worker daemon for the remote executor backend.

Run one of these per measurement host, point it at the objective it
should serve, and hand the tuner the ``host:port`` list:

    # on each measurement host
    PYTHONPATH=src python -m repro.launch.worker --port 9123 --slots 2 \
        --objective benchmarks.perf_iterations:make_remote_bench_objective()

    # on the tuner host
    PYTHONPATH=src python -m repro.launch.tune --arch qwen2-0.5b \
        --backend remote --workers hostA:9123,hostB:9123 ...

(For the roofline objective specifically, ``launch/tune.py
--serve-worker`` is the turnkey spelling: it builds the same
``RooflineEvaluator`` the driver would and serves it, so both ends are
guaranteed to agree on the objective.)

``--objective module:attr`` names the objective; append ``()`` to call
it as a zero-argument factory (the usual shape — a factory builds the
evaluator *on the worker*, so heavyweight state like compile caches
never crosses the wire).  The resolved object may be an
``Evaluator``/``(value, meta)`` callable or a plain scalar objective;
``as_evaluator`` normalizes it exactly as the local backends do.

The daemon registers with the connecting tuner, heartbeats every
``--heartbeat-s`` seconds, pulls ``(point, fidelity)`` tasks into a
``--slots``-wide measurement pool, and streams results back in
completion order.  With ``--join HOST:PORT`` the direction flips: the
daemon dials a *running* tuner's join socket and registers mid-run
(elastic fleets) — the session is otherwise identical.  It never touches the memo cache — results are
persisted by the tuner host, so workers need no shared filesystem.  A
tuner disconnect ends the session and the daemon goes back to
accepting, so a fleet survives tuner restarts.
"""
from __future__ import annotations

import argparse
import importlib
import os
import signal
import traceback

from repro.tuning.remote import DEFAULT_HEARTBEAT_S, WorkerServer


def resolve_objective(spec: str):
    """``module:attr`` or ``module:factory()`` -> the objective object.

    Every failure mode raises with a message that names the spec and
    the precise step that broke (malformed spec, unimportable module,
    missing attribute, raising factory) — this text travels to the
    tuner in the register reply when the daemon serves in error mode,
    so the *submitting* side sees why its fleet cannot measure.
    """
    mod_name, sep, attr = spec.partition(":")
    if not sep or not attr or not mod_name:
        raise ValueError(
            f"objective spec {spec!r} is not module:attr (append () to "
            "call a zero-arg factory, e.g. pkg.mod:make_objective())")
    call = attr.endswith("()")
    if call:
        attr = attr[:-2]
    if not attr.isidentifier():
        raise ValueError(
            f"objective spec {spec!r}: {attr!r} is not a plain attribute "
            "name (only zero-arg factory calls are supported — spell "
            "arguments into a wrapper factory instead)")
    try:
        module = importlib.import_module(mod_name)
    except ImportError as e:
        raise ValueError(
            f"objective spec {spec!r}: cannot import module "
            f"{mod_name!r}: {e!r}") from e
    try:
        obj = getattr(module, attr)
    except AttributeError:
        raise ValueError(
            f"objective spec {spec!r}: module {mod_name!r} has no "
            f"attribute {attr!r}") from None
    if not call:
        return obj
    try:
        return obj()
    except Exception as e:
        raise ValueError(
            f"objective spec {spec!r}: factory {mod_name}:{attr} raised "
            f"{e!r}") from e


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Serve measurements to a remote-backend tuner "
                    "(see repro.tuning.remote for the wire protocol).")
    ap.add_argument("--objective", required=True,
                    help="module:attr naming the objective to serve; "
                         "append () to call it as a zero-arg factory")
    ap.add_argument("--host", default="0.0.0.0",
                    help="interface to listen on (default: all)")
    ap.add_argument("--port", type=int, default=None,
                    help="port to listen on (0 = ephemeral, printed; "
                         "default 9123, or ephemeral with --join)")
    ap.add_argument("--slots", type=int, default=1,
                    help="concurrent measurements this host runs "
                         "(fleet parallelism = sum of slots)")
    ap.add_argument("--heartbeat-s", "--heartbeat", dest="heartbeat_s",
                    type=float, default=DEFAULT_HEARTBEAT_S,
                    help="seconds between heartbeats (the tuner declares "
                         "this worker dead after 3 missed ones)")
    ap.add_argument("--join", default=None, metavar="HOST:PORT",
                    help="elastic mode: dial a running tuner's join socket "
                         "and register mid-run instead of listening for "
                         "tuners to connect here")
    ap.add_argument("--join-retry-s", type=float, default=None,
                    help="with --join: keep re-dialing every N seconds "
                         "through tuner restarts (default: one-shot — "
                         "serve one session and exit)")
    ap.add_argument("--fingerprint-tag", default=None,
                    help="append a tag to the hardware fingerprint shipped "
                         "at register time (testing: simulate distinct "
                         "hardware partitions on one host)")
    ap.add_argument("--serve-startup-error", action="store_true",
                    help="when the objective fails to resolve, keep serving "
                         "in error mode (register replies carry the error, "
                         "so connecting tuners fail loudly with the real "
                         "cause) instead of exiting")
    args = ap.parse_args(argv)

    # resolve at STARTUP, loudly: a bad --objective must never look like
    # a healthy worker.  The default is to crash the daemon with the full
    # traceback; --serve-startup-error keeps the port open and ships the
    # error to every tuner that registers, for fleets managed by
    # supervisors where a crash loop would just look like "unreachable".
    objective, startup_error = None, None
    try:
        objective = resolve_objective(args.objective)
    except ValueError as e:
        print(f"[worker] OBJECTIVE FAILED AT STARTUP: {e}", flush=True)
        traceback.print_exc()
        if not args.serve_startup_error:
            raise
        startup_error = str(e)

    port = args.port if args.port is not None else (0 if args.join else 9123)
    server = WorkerServer(objective,
                          host=args.host, port=port,
                          slots=args.slots, heartbeat_s=args.heartbeat_s,
                          startup_error=startup_error)
    if args.fingerprint_tag is not None:
        server.fingerprint = dict(server.fingerprint,
                                  tag=args.fingerprint_tag)
    if startup_error is not None:
        print(f"[worker] pid={os.getpid()} serving ERROR MODE on "
              f"{server.host}:{server.port} — registering tuners will be "
              "told the startup error", flush=True)
    elif args.join:
        print(f"[worker] pid={os.getpid()} joining fleet at {args.join} "
              f"with {args.objective!r} (slots={server.slots})", flush=True)
    else:
        print(f"[worker] pid={os.getpid()} serving {args.objective!r} on "
              f"{server.host}:{server.port} (slots={server.slots})",
              flush=True)
    if args.join:
        # SIGTERM on a joined daemon = clean deregistration: tell the
        # pool we are leaving so it drains our in-flight results instead
        # of burning a stall window on reinjection.  (SIGKILL still
        # exercises the crash path, deliberately.)
        def _leave(signum, frame):
            print("[worker] SIGTERM: leaving fleet cleanly", flush=True)
            server.request_leave()
        signal.signal(signal.SIGTERM, _leave)
    try:
        if args.join:
            server.join(args.join, retry_s=args.join_retry_s)
        else:
            server.serve_forever()
    except KeyboardInterrupt:
        print("[worker] interrupted; shutting down")
    return server


if __name__ == "__main__":
    main()
