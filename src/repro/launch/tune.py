import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ the roofline objective compiles against the production mesh.

"""The paper's tuning framework applied to this framework's own backend.

    PYTHONPATH=src python -m repro.launch.tune --arch qwen3-moe-30b-a3b \
        --shape train_4k --algo bo --budget 50 --out artifacts/tune_moe.json \
        --parallelism 4 --wall-clock 1800

Each evaluation lowers+compiles the (arch x shape) cell on the production
mesh with the candidate BackendConfig and returns roofline throughput;
OOM configurations fail (-inf) like crashed measurements in the paper.
This driver is also the §Perf hillclimbing engine.

Completion-driven evaluation: the engine keeps ``--parallelism`` workers
full and is told each result the moment its compile finishes (XLA
compilation releases the GIL, so the default thread backend scales); no
worker idles behind one slow configuration.  ``--loop batch`` restores
the legacy per-batch barrier for comparison.  ``--wall-clock`` caps
tuning by seconds instead of / in addition to iterations and bounds
in-flight work: compiles still unfinished at the deadline are abandoned
unrecorded (enforceable with the pool backends, which a wall-clock
budget selects by default; a forced serial backend can only stop
between evaluations), and
``--eval-timeout`` scores any configuration that compiles for too long
as a failure instead of stalling the run.  ``--memo-cache`` persists
every measurement to a file-locked on-disk store, so repeated or resumed
runs (and other hosts sharing the filesystem) re-evaluate nothing.
``--cost-aware`` (BO) switches the acquisition to EI-per-second: a
second GP predicts each candidate's measurement cost and the engine
prefers cheap probes, ramping the preference in as ``--wall-clock``
nears exhaustion.  ``--multi-fidelity`` layers successive-halving rungs
over the loop: candidates are screened with the cheap fast-analysis
compile (one compile instead of three), the top ``1/eta`` survivors are
promoted to the full analysis depth, and in-flight promotions that have
been outclassed are preempted; ``--budget`` then counts full-measurement
equivalents.  The roofline objective has exactly two analysis depths, so
the default ladder is the matching 2-rung one (``--mf-min-fidelity``).

Multi-host tuning splits this driver across machines: run a measurement
worker per host and point one tuner at the fleet.

    # each measurement host serves the same (arch x shape) objective
    PYTHONPATH=src python -m repro.launch.tune --arch qwen2-0.5b \
        --serve-worker --worker-port 9123 --parallelism 2

    # the tuner host drives the fleet (engine, history, and memo cache
    # stay here; workers need no shared filesystem)
    PYTHONPATH=src python -m repro.launch.tune --arch qwen2-0.5b \
        --backend remote --workers hostA:9123,hostB:9123 \
        --memo-cache artifacts/memo.json --budget 50

``--workers`` implies ``--backend remote``; effective parallelism is
the fleet's slot total (``--parallelism`` on the *worker* side sets how
many concurrent compiles that host runs).  A worker dying mid-run is
survived: its in-flight measurements are reinjected onto surviving
workers, never recorded as failed configurations.  The wire protocol
(length-prefixed JSON over TCP: register, heartbeat, task, result) is
documented in ``repro.tuning.remote``; any objective can be served with
the generic ``python -m repro.launch.worker`` daemon.

Tuning as a service: ``--submit-to host:port`` ships the run as a *job*
to a long-lived ``launch/service.py`` daemon (which multiplexes many
jobs over one shared fleet, fair-share scheduled, crash-resumable) and
streams its progress here; ``--detach`` just prints the job id.
"""
import argparse
import math
import pathlib

from repro.configs import get_config
from repro.core import SearchSpace, TransferConfig, Tuner, TunerConfig
from repro.tuning.evaluator import RooflineEvaluator
from repro.tuning.parameters import BASELINE, backend_space, config_from_point


def _transfer_config(args):
    """--corpus: record into / warm-start from an observation corpus."""
    if not args.corpus:
        return None
    return TransferConfig(
        corpus_path=args.corpus,
        job_id=f"{args.arch}:{args.shape}:{args.algo}:seed{args.seed}")


def _apply_scheduler(args, tc):
    """--scheduler + per-scheduler knobs -> the nested mf sub-config.
    A non-ASHA scheduler implies multi-fidelity mode (that is the loop
    the schedulers drive), so --multi-fidelity may be omitted."""
    tc.multi_fidelity.scheduler = args.scheduler
    if args.scheduler != "asha":
        tc.multi_fidelity.enabled = True
    tc.multi_fidelity.hyperband.brackets = args.hb_brackets
    tc.multi_fidelity.pbt.population = args.pbt_population
    tc.multi_fidelity.pbt.exploit_quantile = args.pbt_quantile
    tc.multi_fidelity.pbt.perturb_prob = args.pbt_perturb_prob
    tc.multi_fidelity.pbt.step_fidelity = args.pbt_step_fidelity
    return tc


def _submit(args, space):
    """--submit-to: ship the run to a service daemon, stream its progress."""
    from repro.launch.service import ServiceClient, print_status
    from repro.tuning.protocol import JobSpec

    config = _apply_scheduler(args, TunerConfig(
        algorithm=args.algo, budget=args.budget, seed=args.seed,
        loop=args.loop, cost_aware=args.cost_aware,
        wall_clock_budget=args.wall_clock,
        parallelism=args.parallelism,
        eval_timeout=args.eval_timeout,
        memo_cache_path=args.memo_cache,
        multi_fidelity=args.multi_fidelity,
        mf_eta=args.mf_eta, mf_min_fidelity=args.mf_min_fidelity,
        mf_preempt=not args.no_mf_preempt,
        transfer=_transfer_config(args),
    )).to_dict()
    spec = JobSpec(
        space=space.to_dicts(), config=config,
        name=args.job_name or f"{args.arch} x {args.shape} x {args.algo}",
        objective=args.job_objective)
    with ServiceClient(args.submit_to) as client:
        job_id = client.submit(spec)
        print(f"[tune] submitted {job_id} to {args.submit_to} "
              f"(service slots={client.slots})")
        if args.detach:
            print(f"[tune] watch with: python -m repro.launch.service "
                  f"--connect {args.submit_to} --status {job_id} --watch")
            return job_id

        last = {"n": -1}

        def report(st):
            if st.get("n_evals", 0) != last["n"]:
                last["n"] = st.get("n_evals", 0)
                print_status(st)

        final = client.wait(job_id, on_status=report, poll_s=0.5)
        print_status(final)
        best = final.get("best")
        if best:
            print(f"[tune] best throughput {best['value']:.4g} tok/s at "
                  f"{best['point']}")
            print(f"[tune] backend config: "
                  f"{config_from_point(best['point'], BASELINE)}")
        elif final.get("state") == "failed":
            raise SystemExit(f"[tune] job failed: {final.get('error')}")
        return final


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--algo", default="bo",
                    choices=["bo", "ga", "nms", "random", "exhaustive"])
    ap.add_argument("--budget", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--cache", default=None,
                    help="JSON cache of compiled evaluations (shared across algos)")
    ap.add_argument("--parallelism", type=int, default=1,
                    help="evaluation worker-pool width (1 = sequential loop)")
    ap.add_argument("--backend", "--executor-backend",
                    dest="executor_backend", default=None,
                    choices=["serial", "thread", "process", "remote"],
                    help="worker-pool backend (default: serial for "
                         "parallelism 1, thread above, remote when "
                         "--workers is given)")
    ap.add_argument("--workers", default=None,
                    help="comma-separated host:port measurement workers "
                         "(launch/worker.py daemons or --serve-worker "
                         "instances; implies --backend remote; effective "
                         "parallelism = the fleet's slot total)")
    ap.add_argument("--serve-worker", action="store_true",
                    help="run as a measurement worker instead of a tuner: "
                         "serve this (arch x shape) roofline objective to a "
                         "remote-backend tuner; --parallelism sets the "
                         "concurrent-measurement slots")
    ap.add_argument("--worker-host", default="0.0.0.0",
                    help="--serve-worker: interface to listen on")
    ap.add_argument("--worker-port", type=int, default=9123,
                    help="--serve-worker: port to listen on (0 = ephemeral, "
                         "printed at startup)")
    ap.add_argument("--eval-timeout", type=float, default=None,
                    help="seconds per evaluation before it scores -inf")
    ap.add_argument("--heartbeat-s", type=float, default=None,
                    help="worker heartbeat interval: with --serve-worker the "
                         "interval this daemon beats at; on the tuner side "
                         "the fleet-wide fallback (each worker's stall "
                         "window is 3 missed beats of its registered value)")
    ap.add_argument("--fleet-port", type=int, default=None,
                    metavar="PORT",
                    help="remote backend: keep a join socket open for the "
                         "whole run so launch/worker.py --join daemons can "
                         "register mid-run (0 = ephemeral, printed; default "
                         "0; with an explicit --fleet-port, --workers may be "
                         "empty — the fleet starts when the first worker "
                         "dials in)")
    ap.add_argument("--fleet-homogeneity", default="strict",
                    choices=["strict", "normalize"],
                    help="mixed hardware fingerprints in one fleet: strict "
                         "(default) refuses them; normalize admits them and "
                         "calibrates cost_seconds across partitions from "
                         "duplicate completions")
    ap.add_argument("--no-speculation", action="store_true",
                    help="remote backend: disable speculative re-execution "
                         "of straggling measurements")
    ap.add_argument("--speculation-factor", type=float, default=4.0,
                    help="duplicate an in-flight measurement once its age "
                         "exceeds this multiple of the per-fidelity p95 "
                         "completion time (first result wins, recorded once)")
    ap.add_argument("--wall-clock", type=float, default=None,
                    help="stop tuning after this many seconds (wall-clock "
                         "budget mode; combines with --budget; also bounds "
                         "in-flight evaluations)")
    ap.add_argument("--loop", default="async", choices=["async", "batch"],
                    help="async = completion-driven scheduler (default); "
                         "batch = legacy per-batch barrier")
    ap.add_argument("--memo-cache", default=None,
                    help="disk-backed memo cache of evaluated points "
                         "(atomic + file-locked; shared across runs/hosts)")
    ap.add_argument("--corpus", default=None,
                    help="persistent observation corpus for transfer "
                         "learning: record every completed evaluation, "
                         "warm-start the BO surrogate from neighboring "
                         "workloads recorded by earlier runs, and pre-"
                         "filter candidate batches against them")
    ap.add_argument("--cost-aware", action="store_true",
                    help="BO only: EI-per-second acquisition — trade "
                         "expected improvement against predicted measurement "
                         "cost, preferring cheap probes as --wall-clock "
                         "nears exhaustion")
    ap.add_argument("--multi-fidelity", action="store_true",
                    help="successive-halving (ASHA) rungs: screen candidates "
                         "with cheap fast-analysis compiles, promote the top "
                         "1/eta per rung to full analysis depth; --budget "
                         "then counts full-measurement equivalents")
    ap.add_argument("--mf-eta", type=float, default=3.0,
                    help="rung reduction factor (fidelity ratio and survivor "
                         "fraction between adjacent rungs)")
    ap.add_argument("--mf-min-fidelity", type=float, default=0.33,
                    help="bottom-rung fidelity floor (fraction of a full "
                         "measurement).  The roofline objective has two "
                         "analysis depths (fast vs full), so the default "
                         "builds the matching 2-rung ladder [1/3, 1]; a "
                         "deeper ladder would re-serve identical fast "
                         "results at the middle rungs while still charging "
                         "budget for them")
    ap.add_argument("--no-mf-preempt", action="store_true",
                    help="disable preemption of in-flight promotions whose "
                         "source rung has since outclassed them")
    ap.add_argument("--scheduler", default="asha",
                    choices=["asha", "hyperband", "pbt"],
                    help="trial scheduler driving the multi-fidelity loop "
                         "(implies --multi-fidelity when not asha): asha = "
                         "one successive-halving ladder; hyperband = several "
                         "ASHA brackets with staggered min-fidelities, "
                         "budget split by completion; pbt = population-based "
                         "training (exploit/explore forks over mutating "
                         "points, warm-started via checkpoint-fork where the "
                         "objective supports it)")
    ap.add_argument("--hb-brackets", type=int, default=None,
                    help="hyperband: number of brackets (default: one per "
                         "rung of the deepest ladder)")
    ap.add_argument("--pbt-population", type=int, default=6,
                    help="pbt: steady-state population size")
    ap.add_argument("--pbt-quantile", type=float, default=0.25,
                    help="pbt: cull (bottom) and donor (top) quantile")
    ap.add_argument("--pbt-perturb-prob", type=float, default=0.25,
                    help="pbt: per-dimension mutation probability of an "
                         "explore step (at least one dim always moves)")
    ap.add_argument("--pbt-step-fidelity", type=float, default=None,
                    help="pbt: fidelity of each step (default: "
                         "--mf-min-fidelity)")
    ap.add_argument("--submit-to", default=None, metavar="HOST:PORT",
                    help="thin-client mode: submit this tuning run as a job "
                         "to a running launch/service.py daemon instead of "
                         "tuning locally, then stream its progress (the "
                         "daemon owns the measurement substrate — a remote "
                         "worker fleet or its --objective)")
    ap.add_argument("--job-name", default=None,
                    help="--submit-to: label for the job (default: "
                         "arch x shape x algo)")
    ap.add_argument("--job-objective", default=None,
                    help="--submit-to: module:factory() objective spec the "
                         "daemon should measure for this job (local-"
                         "measurement daemons only)")
    ap.add_argument("--detach", action="store_true",
                    help="--submit-to: print the job id and exit instead of "
                         "streaming progress")
    args = ap.parse_args(argv)
    if args.cost_aware and args.algo != "bo":
        ap.error("--cost-aware requires --algo bo")
    if args.submit_to and args.serve_worker:
        ap.error("--submit-to (thin client) and --serve-worker (measurement "
                 "daemon) are different processes")
    workers = ([w.strip() for w in args.workers.split(",") if w.strip()]
               if args.workers else None)
    if (args.executor_backend == "remote" and not workers
            and args.fleet_port is None):
        ap.error("--backend remote needs --workers host:port,... "
                 "(or an explicit --fleet-port to start an empty elastic "
                 "fleet that workers --join mid-run)")

    cfg = get_config(args.arch)
    shape_kind = "train" if args.shape.startswith("train") else "serve"
    space = SearchSpace.from_dicts(backend_space(cfg, kind=shape_kind))
    print(f"[tune] space: {space.names} (grid {space.grid_size():,})")

    if args.submit_to:
        # thin client: the daemon measures; this process only submits the
        # (space, config) job and renders progress.  No evaluator — and
        # none of its compile state — is built here.
        return _submit(args, space)

    evaluator = RooflineEvaluator(
        args.arch, args.shape, multi_pod=args.multi_pod, cache_path=args.cache
    )
    if args.serve_worker:
        # worker mode: serve this cell's objective to a remote tuner.  The
        # evaluator (and its compile cache) lives here; only points and
        # results cross the wire, and the tuner host persists the memo.
        from repro.tuning.remote import DEFAULT_HEARTBEAT_S, WorkerServer

        server = WorkerServer(evaluator, host=args.worker_host,
                              port=args.worker_port,
                              slots=max(1, args.parallelism),
                              heartbeat_s=(args.heartbeat_s
                                           or DEFAULT_HEARTBEAT_S))
        print(f"[tune] serving measurement worker for ({args.arch} x "
              f"{args.shape}) on {server.host}:{server.port} "
              f"(slots={server.slots}); point the tuner at it with "
              f"--backend remote --workers <host>:{server.port}")
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            print("[tune] worker interrupted; shutting down")
        return None
    ckpt = (args.out + ".ckpt") if args.out else None
    tc = TunerConfig(algorithm=args.algo, budget=args.budget, seed=args.seed,
                     checkpoint_path=ckpt,
                     parallelism=args.parallelism,
                     executor_backend=args.executor_backend,
                     eval_timeout=args.eval_timeout,
                     wall_clock_budget=args.wall_clock,
                     loop=args.loop,
                     memo_cache_path=args.memo_cache,
                     cost_aware=args.cost_aware,
                     multi_fidelity=args.multi_fidelity,
                     mf_eta=args.mf_eta,
                     mf_min_fidelity=args.mf_min_fidelity,
                     mf_preempt=not args.no_mf_preempt,
                     workers=workers,
                     transfer=_transfer_config(args))
    _apply_scheduler(args, tc)
    # elastic-fleet knobs (remote backend only; no flat-kwarg legacy names)
    if args.fleet_port is not None:
        tc.executor.fleet_port = args.fleet_port
    tc.executor.fleet_homogeneity = args.fleet_homogeneity
    tc.executor.speculation = not args.no_speculation
    tc.executor.speculation_factor = args.speculation_factor
    tc.executor.heartbeat_s = args.heartbeat_s
    tuner = Tuner(evaluator, space, tc)
    pool = tuner.executor.remote_pool
    if pool is not None and pool.join_address:
        print(f"[tune] elastic fleet: workers can join mid-run with "
              f"launch/worker.py --join <host>:"
              f"{pool.join_address.rsplit(':', 1)[1]}")
    history = tuner.run()
    tuner.close()
    sched = tuner.rung_scheduler
    if sched is not None:
        kind = getattr(sched, "kind", "asha")
        for row in sched.stats():
            if kind == "pbt":
                print(f"[tune] population: members={row['members']} "
                      f"steps={row['steps']} forks={row['forks']} "
                      f"preempted={row['preempted']} best={row['best']} "
                      f"median={row['median']}")
            else:
                bracket = (f"bracket {row['bracket']} "
                           if "bracket" in row else "")
                print(f"[tune] {bracket}rung {row['rung']} "
                      f"(fidelity {row['fidelity']}): "
                      f"started={row['started']} "
                      f"completed={row['completed']} "
                      f"promoted={row['promoted']} "
                      f"preempted={row['preempted']}")
    if not any(math.isfinite(e.value) for e in history.evals):
        print(f"[tune] no successful evaluations "
              f"({len(history)} run, all failed or budget expired first)")
        if args.out:
            out = pathlib.Path(args.out)
            out.parent.mkdir(parents=True, exist_ok=True)
            out.write_text(history.to_json())
        return history
    full_only = (tc.multi_fidelity.enabled
                 and any(e.fidelity >= 1.0 and math.isfinite(e.value)
                         for e in history.evals))
    best = history.best(full_fidelity_only=full_only)
    print(f"[tune] best throughput {best.value:.4g} tok/s at {best.point}")
    print(f"[tune] backend config: {config_from_point(best.point, BASELINE)}")
    print(f"[tune] sampled-range coverage: {history.sampled_range_fraction()}")
    if args.out:
        out = pathlib.Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(history.to_json())
        print(f"[tune] wrote {out}")
    return history


if __name__ == "__main__":
    main()
