import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^^^ MUST precede any jax import: jax locks the device count on first init.
#     (setdefault so test harnesses can inject a smaller placeholder count.)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces
  * ``memory_analysis()``        — proves the step fits per-device HBM
  * ``cost_analysis()``          — per-device HLO FLOPs / bytes
  * collective-bytes breakdown   — parsed from the SPMD HLO text, while-body
                                   ops scaled by known_trip_count
  * the three-term roofline      — tuning/cost_model.py

HloCostAnalysis counts scan (while) bodies ONCE, so FLOPs/bytes come from
two extra *unrolled* compiles at 1 and 2 layer-periods, extrapolated
linearly to the full depth (exact: the out-of-loop part cancels).

CLI:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --all --out artifacts/dryrun
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, applicable, get_config, get_shape, list_archs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import ShardingRules, active_rules
from repro.launch.mesh import make_mesh
from repro.models.model import build_model
from repro.models.params import split_params
from repro.optim.optimizer import OptimizerConfig, adamw_init, optimizer_state_axes
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train.train_step import make_train_step
from repro.tuning.cost_model import (
    Roofline,
    analytic_hbm_traffic,
    kernel_traffic_bytes,
    model_flops,
    tokens_per_step,
    weighted_collective_bytes,
)
from repro.tuning.hlo_analysis import (
    collect_collective_stats,
    cost_with_scan_correction,
    traffic_analysis,
)
from repro.tuning.parameters import BASELINE, BackendConfig

_METRIC_KEYS = ("loss", "ce", "aux", "lr", "grad_norm", "clip", "loss_out")


def eval_shape_with_axes(init_fn):
    """eval_shape a P-pytree builder: returns (value ShapeDtypeStructs, axes).

    The logical-axes tree (static strings) is captured via a side channel
    during the abstract trace so nothing is ever allocated."""
    box = {}

    def values_only():
        values, axes = split_params(init_fn())
        box["axes"] = axes
        return values

    struct = jax.eval_shape(values_only)
    return struct, box["axes"]


def build_cell_mesh(bc: BackendConfig, *, multi_pod: bool, chips_per_pod: int = 256):
    dp, tp = bc.dp(chips_per_pod), bc.tp(chips_per_pod)
    if multi_pod:
        return make_mesh((2, dp, tp), ("pod", "data", "model"))
    return make_mesh((dp, tp), ("data", "model"))


def _replicated(mesh):
    from jax.sharding import NamedSharding, PartitionSpec

    return NamedSharding(mesh, PartitionSpec())


def lower_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    bc: BackendConfig,
):
    """Lower one cell.  Returns (lowered, meta dict)."""
    model = build_model(cfg)
    rt = bc.runtime()
    overrides = None
    if bc.cache_shard == "heads":
        # decode attention locality: shard the KV cache by kv-heads instead
        # of seq (keeps attention shard-local; no per-token KV all-gather)
        overrides = {"cache_seq": None}
    rules = ShardingRules(mesh, bc.sharding_style, overrides=overrides)

    params_struct, params_axes = eval_shape_with_axes(
        lambda: model.init(jax.random.PRNGKey(0))
    )
    if shape.kind != "train" and bc.serve_bf16_params:
        # beyond-paper: serve from pre-cast bf16 weights (halves weight HBM
        # and the per-token weight traffic of decode)
        params_struct = jax.tree_util.tree_map(
            lambda st: jax.ShapeDtypeStruct(
                st.shape, jnp.bfloat16 if st.dtype == jnp.float32 else st.dtype
            ),
            params_struct,
        )
    params_sh = rules.tree_shardings(params_axes, params_struct)

    specs = model.input_specs(shape)
    batch_struct = {k: v.struct for k, v in specs.items()}
    batch_sh = {
        k: rules.sharding_for(v.logical_axes, v.struct.shape)
        for k, v in specs.items()
    }

    with active_rules(rules):
        if shape.kind == "train":
            opt_cfg = OptimizerConfig(
                state_dtype=bc.opt_state_dtype, factored=bc.factored_opt
            )
            opt_struct = jax.eval_shape(
                lambda p: adamw_init(p, opt_cfg), params_struct
            )
            opt_axes = optimizer_state_axes(params_axes, opt_cfg, params_struct)
            opt_sh = rules.tree_shardings(opt_axes, opt_struct)
            step = make_train_step(model, opt_cfg, rt,
                                   microbatches=bc.microbatches)
            metrics_sh = {k: _replicated(mesh) for k in _METRIC_KEYS}
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, opt_sh, batch_sh),
                out_shardings=(params_sh, opt_sh, metrics_sh),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_struct, opt_struct, batch_struct)
        else:
            cache_struct, cache_axes = eval_shape_with_axes(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            cache_sh = rules.tree_shardings(cache_axes, cache_struct)
            B, V = shape.global_batch, cfg.padded_vocab
            logits_sh = rules.sharding_for(("batch", None, "vocab"), (B, 1, V))
            if shape.kind == "prefill":
                step = make_prefill_step(model, rt)
                jitted = jax.jit(
                    step,
                    in_shardings=(params_sh, batch_sh, cache_sh),
                    out_shardings=(logits_sh, cache_sh),
                    donate_argnums=(2,),
                )
                lowered = jitted.lower(params_struct, batch_struct, cache_struct)
            else:  # decode
                step = make_decode_step(model, rt)
                tok_sh = batch_sh["tokens"]
                jitted = jax.jit(
                    step,
                    in_shardings=(params_sh, tok_sh, cache_sh),
                    out_shardings=(logits_sh, cache_sh),
                    donate_argnums=(2,),
                )
                lowered = jitted.lower(
                    params_struct, batch_struct["tokens"], cache_struct
                )
    return lowered


def _reduced_depth_cfg(cfg: ModelConfig, n_periods: int) -> ModelConfig:
    period = cfg.layer_period()
    kw = {"num_layers": n_periods * period}
    if cfg.encoder_layers:
        kw["encoder_layers"] = n_periods
    return dataclasses.replace(cfg, **kw)


def _compile_costs(cfg, shape, mesh, bc) -> Dict[str, float]:
    lowered = lower_cell(cfg, shape, mesh, bc)
    compiled = lowered.compile()
    out = cost_with_scan_correction(compiled)
    tr = traffic_analysis(compiled.as_text())
    out["traffic_included"] = tr.included_bytes
    out["traffic_excluded"] = tr.excluded_bytes
    return out


def analyze_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    bc: BackendConfig = BASELINE,
    chips_per_pod: int = 256,
    full_text: bool = False,
    fast: bool = False,
) -> Dict:
    """Full dry-run + roofline for one cell."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": True, "skip_reason": reason}

    mesh = build_cell_mesh(bc, multi_pod=multi_pod, chips_per_pod=chips_per_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()

    # 1) full-depth scan compile: memory + collectives (trip-scaled)
    lowered = lower_cell(cfg, shape, mesh, bc)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collect_collective_stats(hlo)
    full_cost = cost_with_scan_correction(compiled)
    t_full = time.time() - t0

    # 2) unrolled 1- and 2-period compiles -> exact flops/bytes extrapolation.
    # block_q is floored for the cost compiles so prefill-32k doesn't unroll
    # 64 chunk bodies (FLOPs are tile-size independent modulo pruning
    # granularity); skipped entirely in fast mode (multi-pod pass, whose
    # deliverable is shard/compile/memory proof — roofline is single-pod).
    n_periods = cfg.num_layers // cfg.layer_period()
    cost_bq = max(bc.block_q, shape.seq_len // 8) if shape.kind != "decode" else bc.block_q
    bc_unroll = bc.replace(unroll_layers=True, block_q=cost_bq)
    # long-period MoE-hybrid bodies (jamba: 8 layers incl. 16-expert MoE)
    # make the unrolled cost compiles pathologically slow on this 1-core
    # host; fall back to trip-count scaling for them (documented few-%%
    # overcount of the out-of-loop part).
    fast = fast or cfg.layer_period() >= 8
    if fast or n_periods == 1:
        tr = traffic_analysis(hlo)
        flops_pd = full_cost["flops"]
        bytes_raw = full_cost["bytes"]
        traffic_in = tr.included_bytes
        traffic_ex = tr.excluded_bytes
        if fast and n_periods > 1:
            # scan bodies counted once: scale by trip count as a first-order
            # correction (exact extrapolation lives in the single-pod pass)
            flops_pd *= n_periods
            bytes_raw *= n_periods
    else:
        c1 = _compile_costs(_reduced_depth_cfg(cfg, 1), shape, mesh, bc_unroll)
        c2 = _compile_costs(_reduced_depth_cfg(cfg, 2), shape, mesh, bc_unroll)
        ex = lambda k: c1[k] + (n_periods - 1) * (c2[k] - c1[k])
        flops_pd = ex("flops")
        bytes_raw = ex("bytes")
        traffic_in = ex("traffic_included")
        traffic_ex = ex("traffic_excluded")
    # Memory term (DESIGN.md §7): three estimates, most->least pessimistic:
    #   bytes_hlo_raw    — cost_analysis on the CPU-lowered HLO (spec formula;
    #                      counts the unfused softmax/scan chains)
    #   traffic_in + kernel credit — per-op traffic with the Pallas-kernel
    #                      regions credited at their true stream traffic
    #   analytic         — TPU-grade-fusion model (headline term)
    kernel_credit = kernel_traffic_bytes(cfg, shape, bc, chips)
    traffic_adjusted = max(traffic_in, 0.0) + kernel_credit
    analytic = analytic_hbm_traffic(cfg, shape, bc, chips)
    bytes_adjusted = analytic["total"]

    mem_per_device = (
        mem.argument_size_in_bytes
        + mem.temp_size_in_bytes
        + mem.output_size_in_bytes
        - mem.alias_size_in_bytes
    )
    n_active = cfg.param_counts()["active"]
    rf = Roofline(
        flops_per_device=flops_pd,
        bytes_per_device=bytes_adjusted,
        collective_bytes=weighted_collective_bytes(coll.bytes_by_kind),
        tokens_per_step=tokens_per_step(shape),
        chips=chips,
        model_flops=model_flops(cfg, shape, n_active),
        memory_per_device=float(mem_per_device),
        collective_detail=coll.summary(),
        bytes_hlo_raw=bytes_raw,
        bytes_kernel_credit=kernel_credit,
    )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "skipped": False,
        "chips": chips,
        "mesh": dict(mesh.shape),
        "backend": dataclasses.asdict(bc),
        "memory": {
            "argument_B": mem.argument_size_in_bytes,
            "temp_B": mem.temp_size_in_bytes,
            "output_B": mem.output_size_in_bytes,
            "alias_B": mem.alias_size_in_bytes,
            "per_device_B": float(mem_per_device),
        },
        "cost": {
            "flops_per_device": flops_pd,
            "bytes_hlo_raw": bytes_raw,
            "bytes_traffic_included": traffic_in,
            "bytes_traffic_kernel_excluded": traffic_ex,
            "bytes_kernel_credit": kernel_credit,
            "bytes_traffic_adjusted": traffic_adjusted,
            "bytes_analytic": analytic,
            "bytes_adjusted": bytes_adjusted,
            "scan_body_flops_once": full_cost["flops"],
            "n_periods": n_periods,
        },
        "collectives": {
            "bytes_by_kind": dict(coll.bytes_by_kind),
            "count_by_kind": dict(coll.count_by_kind),
            "weighted_bytes": weighted_collective_bytes(coll.bytes_by_kind),
        },
        "roofline": rf.row(),
        "params": cfg.param_counts(),
        "compile_seconds": t_full,
    }
    if full_text:
        rec["hlo"] = hlo
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="all (arch x shape) cells")
    ap.add_argument("--out", default=None, help="JSON output path or dir")
    ap.add_argument("--chips-per-pod", type=int, default=256)
    ap.add_argument("--log2-dp", type=int, default=BASELINE.log2_dp)
    ap.add_argument("--style", default=BASELINE.sharding_style)
    ap.add_argument("--remat", default=BASELINE.remat)
    ap.add_argument("--microbatches", type=int, default=BASELINE.microbatches)
    args = ap.parse_args(argv)

    bc = BASELINE.replace(
        log2_dp=args.log2_dp, sharding_style=args.style, remat=args.remat,
        microbatches=args.microbatches,
    )
    results = []

    cells = []
    if args.all:
        for arch in list_archs():
            for shape_name in SHAPES:
                cells.append((arch, shape_name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    done = set()
    if args.out:
        import pathlib

        jl = pathlib.Path(str(args.out) + ".jsonl")
        if jl.exists():  # restart-safe: skip cells already recorded
            for line in jl.read_text().splitlines():
                try:
                    r = json.loads(line)
                    if "error" not in r:
                        done.add((r["arch"], r["shape"], bool(r.get("multi_pod"))))
                        results.append(r)
                except Exception:
                    pass

    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for arch, shape_name in cells:
        for mp in meshes:
            if (arch, shape_name, mp) in done:
                continue
            tag = f"{arch}/{shape_name}/{'multi' if mp else 'single'}"
            try:
                rec = analyze_cell(arch, shape_name, multi_pod=mp, bc=bc,
                                   chips_per_pod=args.chips_per_pod,
                                   fast=mp)
                results.append(rec)
                if rec.get("skipped"):
                    print(f"[dryrun] {tag}: SKIP ({rec['skip_reason']})")
                else:
                    r = rec["roofline"]
                    print(
                        f"[dryrun] {tag}: OK mem/dev "
                        f"{rec['memory']['per_device_B']/1e9:.2f}GB "
                        f"bottleneck={r['bottleneck']} "
                        f"step={r['est_step_s']*1e3:.2f}ms "
                        f"tput={r['throughput_tok_s']:.3g}tok/s "
                        f"compile={rec['compile_seconds']:.0f}s"
                    )
            except Exception as e:  # report, keep going
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape_name,
                                "multi_pod": mp, "error": str(e)})
                print(f"[dryrun] {tag}: FAIL {e}")
            if args.out:  # incremental (restart-safe) record
                import pathlib

                pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
                with open(str(args.out) + ".jsonl", "a") as f:
                    f.write(json.dumps(results[-1], default=str) + "\n")
            sys.stdout.flush()

    if args.out:
        import pathlib

        out = pathlib.Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(results, indent=1, default=str))
        print(f"[dryrun] wrote {out}")
    return results


if __name__ == "__main__":
    main()
