"""Serving steps: batched prefill + single-token decode.

``serve_step`` (decode) is what the assigned ``decode_32k`` / ``long_500k``
shapes lower: one new token for the whole batch against a populated KV /
recurrent-state cache.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.models.runtime import Runtime


def _with_db(rt: Runtime, tuning_db) -> Runtime:
    """Attach a TuningDB to the runtime (trace-time kernel-config lookup);
    ``tuning_db=None`` leaves ``rt`` untouched — byte-identical behavior."""
    if tuning_db is None:
        return rt
    return dataclasses.replace(rt, tuning_db=tuning_db)


def make_prefill_step(model: Model, rt: Runtime, *, tuning_db=None):
    rt = _with_db(rt, tuning_db)

    def prefill_step(params, batch: Dict[str, jax.Array], cache):
        logits, _, new_cache = model.apply(
            params, batch, rt=rt, mode="prefill", cache=cache
        )
        return logits, new_cache

    return prefill_step


def make_decode_step(model: Model, rt: Runtime, *, tuning_db=None):
    rt = _with_db(rt, tuning_db)

    def decode_step(params, tokens: jax.Array, cache):
        return model.decode_step(params, tokens, cache, rt=rt)

    return decode_step


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]


def generate(model: Model, params, batch, *, rt: Runtime, cache, steps: int,
             tuning_db=None):
    """Prefill + greedy decode loop (example/serving driver path)."""
    prefill = make_prefill_step(model, rt, tuning_db=tuning_db)
    decode = make_decode_step(model, rt, tuning_db=tuning_db)
    logits, cache = prefill(params, batch, cache)
    tok = greedy_sample(logits)
    out = [tok]
    for _ in range(steps - 1):
        logits, cache = decode(params, tok, cache)
        tok = greedy_sample(logits)
        out.append(tok)
    return jnp.concatenate(out, axis=1), cache
