"""Mamba-1 selective scan — Pallas TPU kernel.

Hardware adaptation (DESIGN.md §2): the CUDA selective-scan kernel keeps
per-thread state in registers and parallelizes over channels within an SM.
The TPU-native shape of the same insight: parallelize over (batch x channel
blocks) on the *grid*, keep the (block_d, N) state resident in VMEM across
*sequence chunks* (the innermost, sequential grid axis), and vectorize the
time-step recurrence over the channel block on the VPU.  HBM traffic is one
read of x/dt/B/C and one write of y — the state never leaves VMEM.

Grid: ``(B, num_channel_blocks, num_seq_chunks)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(
    x_ref,  # (chunk, block_d)
    dt_ref,  # (chunk, block_d)
    a_ref,  # (block_d, N)
    b_ref,  # (chunk, N)
    c_ref,  # (chunk, N)
    dskip_ref,  # (block_d,)
    y_ref,  # (chunk, block_d)
    h_scr,  # (block_d, N) f32
    *,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)  # (block_d, N)
    dskip = dskip_ref[...].astype(jnp.float32)

    def body(t, _):
        xt = x_ref[t, :].astype(jnp.float32)  # (block_d,)
        dtt = dt_ref[t, :].astype(jnp.float32)
        bt = b_ref[t, :].astype(jnp.float32)  # (N,)
        ct = c_ref[t, :].astype(jnp.float32)
        h = h_scr[...]
        h = jnp.exp(dtt[:, None] * a) * h + (dtt * xt)[:, None] * bt[None, :]
        h_scr[...] = h
        y = jnp.sum(h * ct[None, :], axis=1) + dskip * xt
        y_ref[t, :] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, body, 0)


def ssm_scan(
    x: jax.Array,  # (B, S, D)
    dt: jax.Array,  # (B, S, D)
    A: jax.Array,  # (D, N)
    B_in: jax.Array,  # (B, S, N)
    C_in: jax.Array,  # (B, S, N)
    D_skip: jax.Array,  # (D,)
    *,
    chunk: int = 128,
    block_d: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Returns y (B, S, D).  Zero initial state (training/prefill form)."""
    Bb, S, D = x.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    block_d = min(block_d, D)
    pad_s = (-S) % chunk
    pad_d = (-D) % block_d
    if pad_s:
        f = lambda a: jnp.pad(a, ((0, 0), (0, pad_s), (0, 0)))
        x, dt, B_in, C_in = f(x), f(dt), f(B_in), f(C_in)
    if pad_d:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad_d)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad_d)))
        A = jnp.pad(A, ((0, pad_d), (0, 0)))
        D_skip = jnp.pad(D_skip, ((0, pad_d),))
    Sp, Dp = x.shape[1], x.shape[2]
    nd, nc = Dp // block_d, Sp // chunk

    out = pl.pallas_call(
        functools.partial(_ssm_kernel, chunk=chunk),
        grid=(Bb, nd, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, block_d), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((None, chunk, block_d), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((block_d, N), lambda b, di, ci: (di, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((block_d,), lambda b, di, ci: (di,)),
        ],
        out_specs=pl.BlockSpec(
            (None, chunk, block_d), lambda b, di, ci: (b, ci, di)
        ),
        out_shape=jax.ShapeDtypeStruct((Bb, Sp, Dp), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B_in, C_in, D_skip)
    return out[:, :S, :D]
