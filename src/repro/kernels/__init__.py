"""Pallas TPU kernels for the compute hot-spots, with pure-jnp oracles.

Layout per kernel: ``<name>.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrapper + impl dispatch), ``ref.py`` (oracles).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
