"""Flash attention forward — Pallas TPU kernel.

Tiled online-softmax attention with causal / sliding-window masking and
GQA (grouped KV heads), adapted for the TPU memory hierarchy:

* Grid ``(B*H, num_q_blocks, num_kv_blocks)`` — the KV dimension is the
  innermost (sequential) grid axis, so the fp32 running statistics
  (m, l, acc) live in VMEM scratch across KV steps; HBM traffic is exactly
  one read of Q/K/V and one write of O.
* ``BlockSpec`` tiles: Q ``(block_q, head_dim)``, K/V ``(block_kv,
  head_dim)``.  ``block_q``/``block_kv`` are the backend parameters the
  paper-style tuner optimizes (the KMP_BLOCKTIME analogue — see
  DESIGN.md §2): they trade VMEM footprint against MXU utilization and
  grid overhead.
* Masking is positional (no mask tensor in HBM).  Fully-masked KV tiles
  are still visited but short-circuit to a no-op via ``pl.when`` — tile
  *pruning* for the causal lower-triangle is a documented perf iteration
  (EXPERIMENTS.md §Perf).

Validated against ``ref.attention_ref`` in interpret mode (tests/test_kernels_attention.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _flash_kernel(
    q_ref,  # (block_q, dh)
    k_ref,  # (block_kv, dh)
    v_ref,  # (block_kv, dh)
    o_ref,  # (block_q, dh)
    m_scr,  # (block_q,) f32
    l_scr,  # (block_q,) f32
    acc_scr,  # (block_q, dh) f32
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    seq_q: int,
    seq_kv: int,
    block_q: int,
    block_kv: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    k_pos = ki * block_kv + jax.lax.iota(jnp.int32, block_kv)
    offset = seq_kv - seq_q  # causal alignment for Sq != Skv

    mask = (k_pos[None, :] < seq_kv) & (q_pos[:, None] < seq_q)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None] + offset
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] + offset - window
    elif window is not None:
        mask &= jnp.abs(k_pos[None, :] - q_pos[:, None]) < window

    # skip tiles with no live entry (cheap static-shape branch)
    any_live = jnp.any(mask)

    @pl.when(any_live)
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        s = s * scale
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_next = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.where(m_next == NEG_INF, 0.0, m_next)
        alpha = jnp.exp(m_prev - m_safe)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)

        v = v_ref[...].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_next

    @pl.when(ki == nk - 1)
    def _finalize():
        # A fully-masked query row (padding past seq_q, or a small window
        # with nothing in range) accumulates l == 0; emit exact zeros for
        # it instead of 0/0 NaN.
        l = l_scr[...]
        alive = l > 0.0
        denom = jnp.where(alive, l, 1.0)
        out = jnp.where(alive[:, None], acc_scr[...] / denom[:, None], 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Sq, H, dh)
    k: jax.Array,  # (B, Sk, K, dh)
    v: jax.Array,  # (B, Sk, K, dh)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, dh = q.shape
    _, Sk, K, _ = k.shape
    dv = v.shape[-1]
    assert H % K == 0, (H, K)
    group = H // K
    scale = scale if scale is not None else dh ** -0.5

    block_q = min(block_q, max(Sq, 8))
    block_kv = min(block_kv, max(Sk, 8))
    pad_q = (-Sq) % block_q
    pad_kv = (-Sk) % block_kv

    qt = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, dh)
    kt = jnp.moveaxis(k, 2, 1).reshape(B * K, Sk, dh)
    vt = jnp.moveaxis(v, 2, 1).reshape(B * K, Sk, dv)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        kt = jnp.pad(kt, ((0, 0), (0, pad_kv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pad_kv), (0, 0)))

    nq = qt.shape[1] // block_q
    nk = kt.shape[1] // block_kv

    def kv_index(bh, qi, ki):
        return ((bh // H) * K + (bh % H) // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        seq_q=Sq,
        seq_kv=Sk,
        block_q=block_q,
        block_kv=block_kv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_kv, dh), kv_index),
            pl.BlockSpec((None, block_kv, dv), kv_index),
        ],
        out_specs=pl.BlockSpec((None, block_q, dv), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, qt.shape[1], dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :Sq].reshape(B, H, Sq, dv)
    return jnp.moveaxis(out, 1, 2)
