"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: each Pallas kernel in this package is
validated against the function here across shape/dtype sweeps (interpret
mode on CPU).  They are also the path used by the model zoo for CPU smoke
tests and for the dry-run lowering (XLA:TPU fuses these op-level graphs;
the Pallas kernels' tile parameters enter the roofline analytically).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _expand_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B, S, K, dh) -> (B, S, H, dh) by repeating each kv head H//K times."""
    n_kv = k.shape[2]
    if n_kv == num_heads:
        return k
    assert num_heads % n_kv == 0, (num_heads, n_kv)
    return jnp.repeat(k, num_heads // n_kv, axis=2)


def attention_ref(
    q: jax.Array,  # (B, Sq, H, dh)
    k: jax.Array,  # (B, Sk, K, dh)
    v: jax.Array,  # (B, Sk, K, dh)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    kv_length: Optional[jax.Array] = None,  # (B,) valid kv positions
) -> jax.Array:
    """Softmax attention with GQA, optional causal/sliding-window masking.

    Softmax statistics in fp32 regardless of input dtype (TPU practice).
    """
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = scale if scale is not None else dh ** -0.5

    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s.astype(jnp.float32) * scale

    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    if causal:
        # standard convention: query i attends kv j iff j <= i + (Sk - Sq)
        offset = Sk - Sq
        mask = k_pos <= (q_pos + offset)
        if window is not None:
            mask &= k_pos > (q_pos + offset - window)
    else:
        mask = jnp.ones((Sq, Sk), bool)
        if window is not None:
            mask &= jnp.abs(k_pos - q_pos) < window
    mask = mask[None, None]
    if kv_length is not None:
        mask = mask & (k_pos[None, None] < kv_length[:, None, None, None])
    s = jnp.where(mask, s, NEG_INF)

    # safe softmax (rows that are fully masked produce zeros, not NaNs)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
    return out.astype(q.dtype)


def attention_chunked_ref(
    q: jax.Array,  # (B, Sq, H, dh)
    k: jax.Array,  # (B, Sk, K, dh)
    v: jax.Array,  # (B, Sk, K, dh)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 512,
    unroll: bool = False,
    prune: bool = False,
) -> jax.Array:
    """Memory-efficient attention at the HLO level (Rabe–Staats style):
    scan over query blocks, materializing only (B, H, block_q, Sk) scores.

    This is the op-level stand-in for the Pallas flash kernel in the
    dry-run lowering: its HBM traffic pattern (stream K/V per q-block,
    never materialize Sq x Sk) matches what the kernel does on TPU, so the
    roofline memory term is honest.  ``unroll=True`` replaces the scan
    with a python loop so HloCostAnalysis counts every block (the
    roofline FLOPs-extrapolation path).

    ``prune=True`` (unroll mode only): statically slice each query block's
    K/V to the causally-/window-reachable range — the HLO-level analogue
    of the Pallas kernel's masked-tile skip (flash_attention.py pl.when),
    halving causal attention FLOPs.  The lax.scan path cannot prune
    (uniform trip shapes), matching a kernel without tile skipping."""
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    dv = v.shape[-1]  # may differ from dh (MLA: qk 96, v 64)
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = scale if scale is not None else dh ** -0.5
    block_q = min(block_q, Sq)
    pad = (-Sq) % block_q
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // block_q
    qb = jnp.moveaxis(
        q.reshape(B, nq, block_q, H, dh), 1, 0
    )  # (nq, B, bq, H, dh)
    k_pos = jnp.arange(Sk)[None, :]

    def chunk(qi, qc, kv_lo: int = 0, kv_hi: Optional[int] = None):
        kv_hi = Sk if kv_hi is None else kv_hi
        kc, vc = k[:, kv_lo:kv_hi], v[:, kv_lo:kv_hi]
        kp = k_pos[:, kv_lo:kv_hi]
        q_pos = qi * block_q + jnp.arange(block_q)[:, None]
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            offset = Sk - Sq
            mask = kp <= (q_pos + offset)
            if window is not None:
                mask &= kp > (q_pos + offset - window)
        else:
            mask = jnp.ones((block_q, kv_hi - kv_lo), bool)
            if window is not None:
                mask &= jnp.abs(kp - q_pos) < window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(s - m)
        p = jnp.where(mask[None, None], p, 0.0)
        p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
        return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), vc)

    if unroll:
        outs = []
        for qi in range(nq):
            lo, hi = 0, Sk
            if prune and causal:
                offset = Sk - Sq
                hi = min(Sk, (qi + 1) * block_q + offset)
                if window is not None:
                    lo = max(0, qi * block_q + offset - window + 1)
                hi = max(hi, lo + 1)
            outs.append(chunk(qi, qb[qi], lo, hi))
        out = jnp.stack(outs)
    else:
        # remat per q-block: backward recomputes block scores instead of
        # storing (nq, B, H, block_q, Sk) stacked residuals (this is what
        # the Pallas flash backward does on TPU).
        chunk_ckpt = jax.checkpoint(chunk, prevent_cse=False)
        _, out = jax.lax.scan(
            lambda c, xs: (c, chunk_ckpt(xs[0], xs[1])), None,
            (jnp.arange(nq), qb),
        )
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * block_q, H, dv)[:, :Sq]
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # (B, H, dh)  — one new token per sequence
    k: jax.Array,  # (B, Smax, K, dh) ring/linear KV cache
    v: jax.Array,  # (B, Smax, K, dh)
    lengths: jax.Array,  # (B,) number of valid cache positions
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    out = attention_ref(
        q[:, None], k, v, causal=False, scale=scale, kv_length=lengths
    )
    return out[:, 0]


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    # fp32 only in reductions — never materializes an fp32 copy of x, in
    # the forward OR the backward.  (A full-width upcast in either pass
    # becomes a saved/hoisted scan residual under remat and doubles the
    # per-layer activation footprint; see EXPERIMENTS.md §Perf.)
    var = jnp.einsum(
        "...d,...d->...", x, x, preferred_element_type=jnp.float32
    ) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv[..., None] * scale.astype(x.dtype)


def _rmsnorm_fwd(x, scale, eps):
    # optimization_barrier: stops XLA:CPU from hoisting the implicit
    # bf16->f32 convert of x out of the layer scan (which would keep an
    # f32 copy of the whole residual stack alive).  On TPU the bf16 dot
    # accumulates in f32 natively and the barrier is free.
    xb = jax.lax.optimization_barrier(x)
    var = jnp.einsum(
        "...d,...d->...", xb, xb, preferred_element_type=jnp.float32
    ) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)
    y = x * inv.astype(x.dtype)[..., None] * scale.astype(x.dtype)
    return y, (x, scale, inv)


def _rmsnorm_bwd(eps, res, gy):
    x, scale, inv = res
    x = jax.lax.optimization_barrier(x)
    D = x.shape[-1]
    gxs = gy * scale.astype(gy.dtype)  # dL/dxhat, in compute dtype
    rowdot = jnp.einsum(
        "...d,...d->...", gxs, x, preferred_element_type=jnp.float32
    )
    coef = (inv ** 3 * rowdot / D).astype(x.dtype)
    dx = inv.astype(x.dtype)[..., None] * gxs - coef[..., None] * x
    xhat_g = jnp.einsum(
        "...d,...d->d", gy * inv.astype(gy.dtype)[..., None], x,
        preferred_element_type=jnp.float32,
    )
    dscale = xhat_g.astype(scale.dtype)
    return dx, dscale


rmsnorm_ref.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


# ---------------------------------------------------------------------------
# Mamba-1 selective scan
# ---------------------------------------------------------------------------


def ssm_scan_ref(
    x: jax.Array,  # (B, S, D)   pre-activation ssm input
    dt: jax.Array,  # (B, S, D)  softplus'd timestep
    A: jax.Array,  # (D, N)      negative (continuous-time) state matrix
    B_in: jax.Array,  # (B, S, N)
    C_in: jax.Array,  # (B, S, N)
    D_skip: jax.Array,  # (D,)
    h0: Optional[jax.Array] = None,  # (B, D, N)
) -> Tuple[jax.Array, jax.Array]:
    """Naive sequential selective scan.  Returns (y (B,S,D), h_final (B,D,N)).

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) outer B_t
    y_t = (h_t @ C_t) + D * x_t
    """
    Bb, S, D = x.shape
    N = A.shape[1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B_in.astype(jnp.float32), C_in.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bb, D, N), jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # (B,D) (B,D) (B,N) (B,N)
        dA = jnp.exp(dtt[..., None] * Af[None])  # (B, D, N)
        dBx = (dtt * xt)[..., None] * Bt[:, None, :]  # (B, D, N)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, Ct)
        return h, y

    inps = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), inps)
    y = jnp.moveaxis(ys, 0, 1) + xf * D_skip.astype(jnp.float32)[None, None]
    return y.astype(x.dtype), h_final


def ssm_scan_chunked_ref(
    x, dt, A, B_in, C_in, D_skip, h0=None, *, chunk: int = 128
) -> Tuple[jax.Array, jax.Array]:
    """Chunked (work-efficient) selective scan: associative scan within a
    chunk, sequential carry across chunks.  Same semantics as ssm_scan_ref
    but with materialization bounded by the chunk size — this is the form
    the model uses for training/prefill (and the Pallas kernel's oracle
    structure)."""
    Bb, S, D = x.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    if S % chunk:
        pad = chunk - S % chunk
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B_in, C_in = map(zpad, (x, dt, B_in, C_in))
    Sp = x.shape[1]
    nc = Sp // chunk
    xf = x.astype(jnp.float32).reshape(Bb, nc, chunk, D)
    dtf = dt.astype(jnp.float32).reshape(Bb, nc, chunk, D)
    Bf = B_in.astype(jnp.float32).reshape(Bb, nc, chunk, N)
    Cf = C_in.astype(jnp.float32).reshape(Bb, nc, chunk, N)
    Af = A.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bb, D, N), jnp.float32)

    def chunk_step(h, inp):
        xc, dtc, Bc, Cc = inp  # (B, T, D), (B, T, D), (B, T, N), (B, T, N)
        # discretize
        dA = dtc[..., None] * Af[None, None]  # (B,T,D,N) log decay
        dBx = (dtc * xc)[..., None] * Bc[:, :, None, :]  # (B,T,D,N)

        # associative scan over T: (a, b) pairs with h_t = a_t h_{t-1} + b_t
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 + a2, jnp.exp(jnp.minimum(a2, 0.0)) * b1 + b2

        loga, b = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        h_in = jnp.exp(loga) * h[:, None]  # contribution of carry-in state
        hs = h_in + b  # (B,T,D,N)
        y = jnp.einsum("btdn,btn->btd", hs, Cc)
        return hs[:, -1], y

    inps = tuple(jnp.moveaxis(a, 1, 0) for a in (xf, dtf, Bf, Cf))
    h_final, ys = jax.lax.scan(
        jax.checkpoint(chunk_step, prevent_cse=False),
        h0.astype(jnp.float32), inps,
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, Sp, D)[:, :S]
    y = y + x.astype(jnp.float32)[:, :S] * D_skip.astype(jnp.float32)[None, None]
    return y.astype(x.dtype), h_final


# ---------------------------------------------------------------------------
# RWKV-6 gated-linear-attention (wkv) scan
# ---------------------------------------------------------------------------


def gla_scan_ref(
    r: jax.Array,  # (B, S, H, dk) receptance
    k: jax.Array,  # (B, S, H, dk)
    v: jax.Array,  # (B, S, H, dv)
    w: jax.Array,  # (B, S, H, dk) per-channel decay in (0, 1)
    u: jax.Array,  # (H, dk)       current-token bonus
    h0: Optional[jax.Array] = None,  # (B, H, dk, dv)
) -> Tuple[jax.Array, jax.Array]:
    """RWKV-6 recurrence (fla convention):

    y_t = r_t @ (S_{t-1} + (u * k_t) ⊗ v_t)
    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
    """
    B, S, H, dk = r.shape
    dv = v.shape[-1]
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((B, H, dk, dv), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp  # (B,H,dk) (B,H,dk) (B,H,dv) (B,H,dk)
        bonus = jnp.einsum("bhk,hk,bhk->bh", rt, uf, kt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S) + bonus[..., None] * vt
        S = wt[..., None] * S + kt[..., None] * vt[:, :, None, :]
        return S, y

    inps = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    S_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), inps)
    y = jnp.moveaxis(ys, 0, 1)  # (B, S, H, dv)
    return y.astype(r.dtype), S_final


def gla_scan_chunked_ref(
    r, k, v, w, u, h0=None, *, chunk: int = 64
) -> Tuple[jax.Array, jax.Array]:
    """Chunked-quadratic GLA: O(S/C * C^2) intra-chunk attention with decay
    products + O(S/C) cross-chunk state carry.  Matmul-friendly form used by
    the model for training/prefill."""
    B, S, H, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    if S % chunk:
        pad = chunk - S % chunk
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        r, k, v = zpad(r), zpad(k), zpad(v)
        w = jnp.pad(w, [(0, 0), (0, pad), (0, 0), (0, 0)], constant_values=1.0)
    Sp = r.shape[1]
    nc = Sp // chunk
    shp = lambda a, d: a.astype(jnp.float32).reshape(B, nc, chunk, H, d)
    rf, kf, wf = shp(r, dk), shp(k, dk), shp(w, dk)
    vf = shp(v, dv)
    uf = u.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((B, H, dk, dv), jnp.float32)

    logw = jnp.log(jnp.maximum(wf, 1e-30))  # (B,nc,T,H,dk)
    cum = jnp.cumsum(logw, axis=2)  # inclusive cumulative log-decay

    def chunk_step(S, inp):
        rc, kc, vc, cumc, logwc = inp  # (B,T,H,*)
        T = rc.shape[1]
        total = cumc[:, -1]  # (B,H,dk) chunk total log decay
        excl = cumc - logwc  # exclusive cumulative log-decay c_{t-1}
        r_dec = rc * jnp.exp(excl)  # r_t * prod_{j<t} w_j
        k_dec = kc * jnp.exp(total[:, None] - cumc)  # k decayed to chunk end
        # intra-chunk quadratic attention with relative decay.  Computed in
        # masked diff-then-exp form: exponents of kept (s < t) entries are
        # always <= 0, so this never overflows (the naive
        # exp(c_{t-1}) * exp(-c_s) product form can hit inf for strong
        # decays; chunk memory is O(T^2 * dk), keep chunks modest).
        tri = jnp.tril(jnp.ones((T, T), bool), k=-1)  # (t, s): s < t
        diff = excl[:, :, None] - cumc[:, None]  # (B,T,S,H,dk)
        diff = jnp.where(tri[None, :, :, None, None], diff, NEG_INF)
        att = jnp.einsum("bthk,bshk,btshk->bhts", rc, kc, jnp.exp(diff))
        bonus = jnp.einsum("bthk,hk,bthk->bht", rc, uf, kc)
        y = jnp.einsum("bhts,bshv->bthv", att, vc)
        y += bonus.transpose(0, 2, 1)[..., None] * vc
        # cross-chunk contribution
        y += jnp.einsum("bthk,bhkv->bthv", r_dec, S)
        # state update
        S = jnp.exp(total)[..., None] * S + jnp.einsum(
            "bthk,bthv->bhkv", k_dec, vc
        )
        return S, y

    inps = tuple(
        jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, cum, logw)
    )
    S_final, ys = jax.lax.scan(
        jax.checkpoint(chunk_step, prevent_cse=False),
        h0.astype(jnp.float32), inps,
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, H, dv)[:, :S]
    return y.astype(r.dtype), S_final
