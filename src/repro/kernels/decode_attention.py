"""Flash-decoding attention — Pallas TPU kernel for the serve_step.

One new query token per sequence attends over a long KV cache.  Decode is
HBM-bandwidth bound (every KV byte is read once per token), so the kernel's
job is to stream KV tiles through VMEM at full bandwidth while keeping the
online-softmax statistics in scratch.

Grid ``(B*H, num_kv_blocks)``; per-sequence valid length arrives via an
SMEM scalar block so ragged batches (continuous batching) mask correctly.
GQA handled by index-map head folding like flash_attention.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _decode_kernel(
    len_ref,  # SMEM (1,) int32 — valid cache length for this sequence
    q_ref,  # (1, dh)
    k_ref,  # (block_kv, dh)
    v_ref,  # (block_kv, dh)
    o_ref,  # (1, dh)
    m_scr,  # (1,) f32
    l_scr,  # (1,) f32
    acc_scr,  # (1, dh) f32
    *,
    scale: float,
    block_kv: int,
):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    k_pos = ki * block_kv + jax.lax.iota(jnp.int32, block_kv)
    mask = k_pos < length

    @pl.when(jnp.any(mask))
    def _compute():
        q = q_ref[...].astype(jnp.float32)  # (1, dh)
        k = k_ref[...].astype(jnp.float32)  # (block_kv, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )[0] * scale  # (block_kv,)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[0]
        m_next = jnp.maximum(m_prev, jnp.max(s))
        m_safe = jnp.where(m_next == NEG_INF, 0.0, m_next)
        alpha = jnp.exp(m_prev - m_safe)
        p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
        v = v_ref[...].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + (p[None, :] @ v)
        l_scr[0] = l_scr[0] * alpha + jnp.sum(p)
        m_scr[0] = m_next

    @pl.when(ki == nk - 1)
    def _finalize():
        # length-0 rows (empty cache slots) accumulate l == 0; emit exact
        # zeros instead of 0/0 NaN
        l = l_scr[0]
        alive = l > 0.0
        denom = jnp.where(alive, l, 1.0)
        out = jnp.where(alive, acc_scr[...] / denom, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,  # (B, H, dh)
    k: jax.Array,  # (B, Smax, K, dh)
    v: jax.Array,  # (B, Smax, K, dh)
    lengths: jax.Array,  # (B,) int32
    *,
    scale: Optional[float] = None,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, dh = q.shape
    _, Smax, K, _ = k.shape
    assert H % K == 0
    group = H // K
    scale = scale if scale is not None else dh ** -0.5

    block_kv = min(block_kv, max(Smax, 8))
    pad = (-Smax) % block_kv
    kt = jnp.moveaxis(k, 2, 1).reshape(B * K, Smax, dh)
    vt = jnp.moveaxis(v, 2, 1).reshape(B * K, Smax, dh)
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pad), (0, 0)))
    qt = q.reshape(B * H, 1, dh)
    lens = jnp.repeat(lengths.astype(jnp.int32), H).reshape(B * H, 1)
    nk = kt.shape[1] // block_kv

    def kv_index(bh, ki):
        return ((bh // H) * K + (bh % H) // group, ki, 0)

    kernel = functools.partial(_decode_kernel, scale=scale, block_kv=block_kv)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nk),
        in_specs=[
            pl.BlockSpec((None, 1), lambda bh, ki: (bh, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, 1, dh), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((None, block_kv, dh), kv_index),
            pl.BlockSpec((None, block_kv, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((None, 1, dh), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, 1, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qt, kt, vt)
    return out.reshape(B, H, dh)
