"""Fused RMSNorm — Pallas TPU kernel.

Row-tiled: grid over row blocks, each block computes fp32 mean-square and
applies the scale in one VMEM pass (fuses what XLA emits as 4+ HBM
round-trips at small sizes).  The feature dimension stays whole in VMEM —
valid for every assigned arch (d_model <= 8192 => <= 32 KiB/row fp32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)[None, :]).astype(o_ref.dtype)


def rmsnorm(
    x: jax.Array,  # (..., D)
    scale: jax.Array,  # (D,)
    eps: float = 1e-5,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    D = x.shape[-1]
    xr = x.reshape(-1, D)
    R = xr.shape[0]
    block_rows = min(block_rows, R)
    pad = (-R) % block_rows
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(xr.shape[0] // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr, scale)
    return out[:R].reshape(orig_shape)
