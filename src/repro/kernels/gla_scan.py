"""RWKV-6 wkv (gated-linear-attention) scan — Pallas TPU kernel.

State ``S (dk, dv)`` per (batch, head) stays in VMEM scratch across the
sequence chunks (innermost grid axis); the per-timestep recurrence is
vectorized over the (dk, dv) state matrix on the VPU.

    y_t = r_t @ (S + (u * k_t) ⊗ v_t)
    S  <- diag(w_t) S + k_t ⊗ v_t

Grid: ``(B*H, num_seq_chunks)``.  The chunked-quadratic (MXU/matmul) form
lives in ref.gla_scan_chunked_ref and is the documented perf iteration for
training shapes; this kernel is the exact, numerically-stable recurrence
used for decode/prefill validation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gla_kernel(
    r_ref,  # (chunk, dk)
    k_ref,  # (chunk, dk)
    v_ref,  # (chunk, dv)
    w_ref,  # (chunk, dk)
    u_ref,  # (dk,)
    y_ref,  # (chunk, dv)
    s_scr,  # (dk, dv) f32
    *,
    chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[...].astype(jnp.float32)

    def body(t, _):
        rt = r_ref[t, :].astype(jnp.float32)  # (dk,)
        kt = k_ref[t, :].astype(jnp.float32)
        vt = v_ref[t, :].astype(jnp.float32)  # (dv,)
        wt = w_ref[t, :].astype(jnp.float32)
        S = s_scr[...]
        bonus = jnp.sum(rt * u * kt)
        y = rt @ S + bonus * vt
        s_scr[...] = wt[:, None] * S + kt[:, None] * vt[None, :]
        y_ref[t, :] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, body, 0)


def gla_scan(
    r: jax.Array,  # (B, S, H, dk)
    k: jax.Array,  # (B, S, H, dk)
    v: jax.Array,  # (B, S, H, dv)
    w: jax.Array,  # (B, S, H, dk) decay in (0, 1)
    u: jax.Array,  # (H, dk)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Returns y (B, S, H, dv).  Zero initial state."""
    B, S, H, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk

    def fold(a, d):
        a = jnp.moveaxis(a, 2, 1).reshape(B * H, S, d)
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        return a

    rt, kt, wt = fold(r, dk), fold(k, dk), fold(w, dk)
    vt = fold(v, dv)
    Sp = rt.shape[1]
    nc = Sp // chunk

    def u_index(bh, ci):
        return (bh % H, 0)

    out = pl.pallas_call(
        functools.partial(_gla_kernel, chunk=chunk),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, dk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, chunk, dk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, chunk, dv), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, chunk, dk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, dk), u_index),
        ],
        out_specs=pl.BlockSpec((None, chunk, dv), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, dv), r.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u)
    return jnp.moveaxis(out[:, :S].reshape(B, H, S, dv), 1, 2)
