"""Public kernel API: jit'd wrappers with implementation dispatch.

``impl``:
  * ``"ref"``    — pure-jnp oracle (differentiable; used on CPU and for the
                   dry-run lowering).
  * ``"pallas"`` — the Pallas TPU kernel.  On a CPU backend it runs in
                   interpret mode automatically (correctness validation).
  * ``"chunked"``— matmul-friendly chunked jnp form (scans only).

Pallas forward passes get a ``jax.custom_vjp`` whose backward recomputes
through the reference implementation — the standard remat-style pairing
that keeps the training graph differentiable while the fwd hot-spot runs
the hand-written kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import decode_attention as _decode_mod
from repro.kernels import flash_attention as _flash_mod
from repro.kernels import gla_scan as _gla_mod
from repro.kernels import rmsnorm as _rms_mod
from repro.kernels import ssm_scan as _ssm_mod
from repro.kernels import ref

_VALID_IMPLS = ("ref", "pallas", "chunked")


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _tuned(db, kernel: str, dims: dict, defaults: dict) -> dict:
    """Trace-time TuningDB consult: best-known tile config for this
    kernel at these call shapes, else the caller's heuristic defaults.

    Runs while the wrapper is being traced (shapes are concrete python
    ints), so a hit rewrites the tile knobs of the jaxpr being built and
    costs nothing per step.  ``db=None`` — the default everywhere — is
    byte-identical to the historical behavior.
    """
    if db is None:
        return defaults
    cfg = db.kernel_config(kernel, dims)
    if not cfg:
        return defaults
    return {k: int(cfg.get(k, v)) for k, v in defaults.items()}


def _ref_vjp(pallas_fn, ref_fn):
    """custom_vjp: pallas forward, reference-recompute backward."""

    @jax.custom_vjp
    def fn(*args):
        return pallas_fn(*args)

    def fwd(*args):
        return pallas_fn(*args), args

    def bwd(args, g):
        _, vjp = jax.vjp(ref_fn, *args)
        return vjp(g)

    fn.defvjp(fwd, bwd)
    return fn


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    impl: str = "ref",
    block_q: int = 128,
    block_kv: int = 128,
    unroll: bool = False,
    prune: bool = False,
    db=None,
) -> jax.Array:
    """(B,Sq,H,dh) x (B,Sk,K,dh) -> (B,Sq,H,dh)."""
    assert impl in _VALID_IMPLS, impl
    if impl == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window, scale=scale)
    B, Sq, H, dh = q.shape
    _, Sk, K, _ = k.shape
    t = _tuned(db, "flash_attention",
               {"B": B, "Sq": Sq, "Sk": Sk, "H": H, "K": K, "dh": dh},
               {"block_q": block_q, "block_kv": block_kv})
    block_q, block_kv = t["block_q"], t["block_kv"]
    if impl == "chunked":
        with jax.named_scope("krnl_flash_attn"):
            return ref.attention_chunked_ref(
                q, k, v, causal=causal, window=window, scale=scale,
                block_q=block_q, unroll=unroll, prune=prune,
            )

    pallas_fn = functools.partial(
        _flash_mod.flash_attention,
        causal=causal,
        window=window,
        scale=scale,
        block_q=block_q,
        block_kv=block_kv,
        interpret=_interpret(),
    )
    ref_fn = functools.partial(
        ref.attention_ref, causal=causal, window=window, scale=scale
    )
    return _ref_vjp(pallas_fn, ref_fn)(q, k, v)


def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    scale: Optional[float] = None,
    impl: str = "ref",
    block_kv: int = 512,
    db=None,
) -> jax.Array:
    """(B,H,dh) x (B,Smax,K,dh) cache + (B,) lengths -> (B,H,dh)."""
    assert impl in _VALID_IMPLS, impl
    if impl in ("ref", "chunked"):
        with jax.named_scope("krnl_decode_attn"):
            return ref.decode_attention_ref(q, k, v, lengths, scale=scale)
    B, H, dh = q.shape
    _, Smax, K, _ = k.shape
    block_kv = _tuned(db, "decode_attention",
                      {"B": B, "H": H, "K": K, "dh": dh, "Smax": Smax},
                      {"block_kv": block_kv})["block_kv"]
    return _decode_mod.decode_attention(
        q, k, v, lengths, scale=scale, block_kv=block_kv, interpret=_interpret()
    )


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm(
    x: jax.Array,
    scale: jax.Array,
    eps: float = 1e-5,
    *,
    impl: str = "ref",
    block_rows: int = 256,
    db=None,
) -> jax.Array:
    assert impl in _VALID_IMPLS, impl
    if impl in ("ref", "chunked"):
        return ref.rmsnorm_ref(x, scale, eps)
    rows = 1
    for d in x.shape[:-1]:
        rows *= int(d)
    block_rows = _tuned(db, "rmsnorm", {"rows": rows, "D": x.shape[-1]},
                        {"block_rows": block_rows})["block_rows"]
    pallas_fn = functools.partial(
        _rms_mod.rmsnorm, eps=eps, block_rows=block_rows, interpret=_interpret()
    )
    ref_fn = functools.partial(ref.rmsnorm_ref, eps=eps)
    return _ref_vjp(pallas_fn, ref_fn)(x, scale)


# ---------------------------------------------------------------------------
# Scans
# ---------------------------------------------------------------------------


def ssm_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B_in: jax.Array,
    C_in: jax.Array,
    D_skip: jax.Array,
    *,
    impl: str = "chunked",
    chunk: int = 128,
    block_d: int = 256,
    db=None,
) -> jax.Array:
    """Selective scan, zero init state.  Returns y (B,S,D)."""
    assert impl in _VALID_IMPLS, impl
    if impl == "ref":
        return ref.ssm_scan_ref(x, dt, A, B_in, C_in, D_skip)[0]
    B, S, D = x.shape
    t = _tuned(db, "ssm_scan",
               {"B": B, "S": S, "D": D, "N": A.shape[-1]},
               {"chunk": chunk, "block_d": block_d})
    chunk, block_d = t["chunk"], t["block_d"]
    if impl == "chunked":
        with jax.named_scope("krnl_ssm_scan"):
            return ref.ssm_scan_chunked_ref(
                x, dt, A, B_in, C_in, D_skip, chunk=chunk
            )[0]
    pallas_fn = functools.partial(
        _ssm_mod.ssm_scan, chunk=chunk, block_d=block_d, interpret=_interpret()
    )
    ref_fn = lambda *a: ref.ssm_scan_chunked_ref(*a, chunk=chunk)[0]
    return _ref_vjp(pallas_fn, ref_fn)(x, dt, A, B_in, C_in, D_skip)


def gla_scan(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    *,
    impl: str = "chunked",
    chunk: int = 64,
    db=None,
) -> jax.Array:
    """RWKV-6 wkv scan, zero init state.  Returns y (B,S,H,dv)."""
    assert impl in _VALID_IMPLS, impl
    if impl == "ref":
        return ref.gla_scan_ref(r, k, v, w, u)[0]
    B, S, H, dk = k.shape
    chunk = _tuned(db, "gla_scan",
                   {"B": B, "S": S, "H": H, "dk": dk, "dv": v.shape[-1]},
                   {"chunk": chunk})["chunk"]
    if impl == "chunked":
        with jax.named_scope("krnl_gla_scan"):
            return ref.gla_scan_chunked_ref(r, k, v, w, u, chunk=chunk)[0]
    pallas_fn = functools.partial(
        _gla_mod.gla_scan, chunk=chunk, interpret=_interpret()
    )
    ref_fn = lambda *a: ref.gla_scan_chunked_ref(*a, chunk=chunk)[0]
    return _ref_vjp(pallas_fn, ref_fn)(r, k, v, w, u)
