"""Loss + train step with microbatched gradient accumulation.

``microbatches`` (the paper's ``batch_size`` analogue in the tuning space)
splits the per-step batch into k sequential microbatches via ``lax.scan``;
gradients accumulate in fp32 and the collective all-reduce/reduce-scatter
that SPMD inserts for data-parallel gradients happens once, after the scan
(deferred reduction — compute/comm overlap trick #1 in DESIGN.md §8).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.models.runtime import Runtime
from repro.optim.optimizer import OptimizerConfig, adamw_update

AUX_LOSS_WEIGHT = 0.01


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean token cross-entropy in fp32.  logits (B,S,V), targets (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_loss_fn(model: Model, rt: Runtime):
    def loss_fn(params, batch: Dict[str, jax.Array]):
        logits, aux, _ = model.apply(params, batch, rt=rt, mode="full")
        ce = cross_entropy(logits, batch["targets"])
        loss = ce + AUX_LOSS_WEIGHT * aux
        return loss, {"loss": loss, "ce": ce, "aux": aux}

    return loss_fn


def _split_microbatches(batch: Dict[str, jax.Array], k: int):
    def sp(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape(k, b // k, *x.shape[1:])

    return {name: sp(v) for name, v in batch.items()}


def make_train_step(model: Model, opt_cfg: OptimizerConfig, rt: Runtime,
                    microbatches: int = 1, *, tuning_db=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``tuning_db`` attaches a :class:`~repro.tuning.tundb.TuningDB` for
    trace-time kernel-config lookup; ``None`` is byte-identical to the
    historical behavior.
    """
    if tuning_db is not None:
        import dataclasses
        rt = dataclasses.replace(rt, tuning_db=tuning_db)
    loss_fn = make_loss_fn(model, rt)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mb = _split_microbatches(batch, microbatches)
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def mb_step(acc, mbatch):
                (loss, metrics), grads = grad_fn(params, mbatch)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads
                )
                return acc, (loss, metrics)

            if rt.unroll_layers:  # exact HloCostAnalysis (roofline pipeline)
                grads, outs = zero_g, []
                for i in range(microbatches):
                    grads, out = mb_step(
                        grads,
                        jax.tree_util.tree_map(lambda a: a[i], mb),
                    )
                    outs.append(out)
                losses = jnp.stack([o[0] for o in outs])
                metricses = jax.tree_util.tree_map(
                    lambda *zs: jnp.stack(zs), *[o[1] for o in outs]
                )
            else:
                grads, (losses, metricses) = jax.lax.scan(mb_step, zero_g, mb)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = losses.mean()
            metrics = jax.tree_util.tree_map(jnp.mean, metricses)

        params, opt_state, opt_metrics = adamw_update(grads, opt_state, params,
                                                      opt_cfg)
        metrics = dict(metrics, **opt_metrics, loss_out=loss)
        return params, opt_state, metrics

    return train_step
