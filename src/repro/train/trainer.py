"""Fault-tolerant training loop.

Wires together model / optimizer / data / checkpointer / straggler
detector.  Failure handling: a ``WorkerFailure`` raised during a step
rolls back to the last checkpoint, applies an ``ElasticPlan`` (dp shrinks,
tp preserved), rebuilds the jitted step, and resumes from the restored
step — the deterministic data pipeline replays the identical stream.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.model import build_model
from repro.models.params import split_params
from repro.models.runtime import Runtime
from repro.optim.optimizer import OptimizerConfig, adamw_init
from repro.runtime.fault_tolerance import (
    ElasticPlan,
    FailureInjector,
    StragglerDetector,
    WorkerFailure,
)
from repro.train.train_step import make_train_step


@dataclass
class TrainerConfig:
    steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: Optional[str] = None
    microbatches: int = 1
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: OptimizerConfig,
        data_cfg: DataConfig,
        tcfg: TrainerConfig,
        rt: Runtime = Runtime(compute_dtype="f32"),
        failure_injector: Optional[FailureInjector] = None,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.data = SyntheticTokens(data_cfg)
        self.tcfg = tcfg
        self.rt = rt
        self.model = build_model(cfg)
        self.failures = failure_injector
        self.straggler = StragglerDetector()
        self.ckpt = (Checkpointer(tcfg.checkpoint_dir)
                     if tcfg.checkpoint_dir else None)
        self.metrics_log: List[Dict] = []
        self.events: List[str] = []

        params_tree = self.model.init(jax.random.PRNGKey(tcfg.seed))
        self.params, self.params_axes = split_params(params_tree)
        self.opt_state = adamw_init(self.params, opt_cfg)
        self._build_step()
        self.step = 0

    def _build_step(self):
        step_fn = make_train_step(self.model, self.opt_cfg, self.rt,
                                  microbatches=self.tcfg.microbatches)
        self._jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    # -- checkpoint/restart ----------------------------------------------------
    def _save(self, metric: Optional[float] = None):
        if not self.ckpt:
            return
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            metadata={"config": self.cfg.name},
            metric=metric,
        )

    def _restore(self):
        assert self.ckpt is not None, "failure without checkpointing enabled"
        like = {"params": self.params, "opt": self.opt_state}
        restored, meta = self.ckpt.restore(None, like)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.step = int(meta["step"])
        self.events.append(f"restored step {self.step}")

    # -- main loop ---------------------------------------------------------------
    def run(self) -> List[Dict]:
        last_metric = None
        if self.ckpt and self.ckpt.latest_step() is not None:
            self._restore()
        while self.step < self.tcfg.steps:
            batch_np = self.data.batch_at(self.step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()}
            t0 = time.perf_counter()
            try:
                if self.failures is not None:
                    self.failures.check(self.step)
                self.params, self.opt_state, metrics = self._jitted(
                    self.params, self.opt_state, batch
                )
            except WorkerFailure as e:
                self.events.append(f"failure at step {e.step}")
                plan = ElasticPlan.after_failure(dp=2, tp=1,
                                                 lost_chips=e.failed_workers)
                self.events.append(
                    f"elastic rescale dp {plan.old_dp}->{plan.new_dp}"
                )
                self._restore()
                self._build_step()  # re-jit for the (new) topology
                continue
            dt = time.perf_counter() - t0
            if self.straggler.update(dt):
                self.events.append(f"straggler flagged at step {self.step}")
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics.update(step=self.step, seconds=dt)
            self.metrics_log.append(metrics)
            last_metric = -metrics["loss"]
            if self.tcfg.log_every and self.step % self.tcfg.log_every == 0:
                print(f"[train] step {self.step:5d} loss {metrics['loss']:.4f} "
                      f"({dt*1e3:.0f} ms)")
            self.step += 1
            if self.ckpt and self.step % self.tcfg.checkpoint_every == 0:
                self._save(metric=last_metric)
        if self.ckpt:
            self._save(metric=last_metric)
            self.ckpt.wait()
        return self.metrics_log
