"""Fault-tolerance runtime: straggler detection, failure injection, and
elastic rescale planning.

At 1000+ nodes these drive the control plane; here the policies are
implemented exactly and exercised single-process (the trainer injects
``WorkerFailure``s and recovers through the checkpoint + rescale path).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


class WorkerFailure(RuntimeError):
    """A (simulated) worker/host loss during a step."""

    def __init__(self, step: int, failed_workers: int = 1):
        super().__init__(f"worker failure at step {step} ({failed_workers} lost)")
        self.step = step
        self.failed_workers = failed_workers


@dataclass
class StragglerDetector:
    """EWMA z-score detector on per-step wall time.

    ``update`` returns True when the step time is a sustained outlier —
    the trainer then flags the replica group for exclusion (elastic path).
    """

    alpha: float = 0.1
    z_threshold: float = 4.0
    warmup: int = 10
    sustained: int = 3

    _mean: float = field(default=0.0, init=False)
    _var: float = field(default=0.0, init=False)
    _n: int = field(default=0, init=False)
    _hits: int = field(default=0, init=False)

    def update(self, step_seconds: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            # prime the statistics
            if self._n == 1:
                self._mean = step_seconds
            self._mean += self.alpha * (step_seconds - self._mean)
            self._var += self.alpha * ((step_seconds - self._mean) ** 2 - self._var)
            return False
        std = math.sqrt(max(self._var, 1e-12))
        z = (step_seconds - self._mean) / std
        is_outlier = z > self.z_threshold
        self._hits = self._hits + 1 if is_outlier else 0
        if not is_outlier:  # only absorb normal samples into the baseline
            self._mean += self.alpha * (step_seconds - self._mean)
            self._var += self.alpha * ((step_seconds - self._mean) ** 2 - self._var)
        return self._hits >= self.sustained

    @property
    def baseline(self) -> float:
        return self._mean


class FailureInjector:
    """Deterministic pseudo-random failure schedule for tests/examples."""

    def __init__(self, rate: float = 0.0, seed: int = 0,
                 at_steps: Optional[List[int]] = None):
        self.rate = rate
        self.rng = np.random.default_rng(seed)
        self.at_steps = set(at_steps or [])

    def check(self, step: int) -> None:
        if step in self.at_steps:
            self.at_steps.discard(step)  # each scheduled failure fires once
            raise WorkerFailure(step)
        if self.rate > 0 and self.rng.random() < self.rate:
            raise WorkerFailure(step)


@dataclass(frozen=True)
class ElasticPlan:
    """Rescale decision after losing workers: keep tp, shrink dp to the
    largest power of two that the survivors support; global batch is
    preserved (per-replica batch grows), so the data stream and loss
    trajectory stay comparable."""

    old_dp: int
    new_dp: int
    tp: int

    @classmethod
    def after_failure(cls, dp: int, tp: int, lost_chips: int) -> "ElasticPlan":
        survivors = dp * tp - lost_chips
        new_dp = 1
        while new_dp * 2 * tp <= survivors:
            new_dp *= 2
        if new_dp < 1:
            raise RuntimeError("not enough survivors for even dp=1")
        return cls(old_dp=dp, new_dp=new_dp, tp=tp)

    @property
    def chips(self) -> int:
        return self.new_dp * self.tp
