from repro.models.model import Model, build_model
from repro.models.runtime import CPU_TEST, Runtime

__all__ = ["Model", "build_model", "Runtime", "CPU_TEST"]
