"""Unified decoder-only LM covering dense / MoE / hybrid / SSM / VLM families.

Layer stacks are applied with ``lax.scan`` over the *repeating period* of
the layer plan (configs/base.py:layer_period): per-period-position
parameters are stacked along a leading ``layers`` dim, so compile time is
depth-independent (62-layer models lower the same HLO as 2-layer ones, just
with a longer scan trip count).

Three entry points: ``forward`` (train / prefill), ``decode_step`` and
``init_cache`` — the KV/recurrent-state cache is itself a P-pytree so the
launcher can shard it (seq over "model", batch over "data"/"pod").
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_hint
from repro.models import layers as L
from repro.models.params import P, dense_init, stack_layer_params
from repro.models.runtime import Runtime

MIXER_INIT = {
    "attn": L.init_attention,
    "mla": L.init_mla,
    "mamba": L.init_mamba,
    "rwkv": L.init_rwkv_tmix,
}


def _scan_periods(period_fn, carry, xs, rt: Runtime):
    """lax.scan over stacked periods, or a python loop when
    rt.unroll_layers (exact HloCostAnalysis for the roofline pipeline)."""
    if not rt.unroll_layers:
        return jax.lax.scan(period_fn, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xs_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = period_fn(carry, xs_i)
        ys.append(y)
    if all(y is None for y in jax.tree_util.tree_leaves(ys, is_leaf=lambda v: v is None)):
        return carry, None
    stacked = jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys)
    return carry, stacked


def _init_block(key, cfg: ModelConfig, mixer_kind: str, mlp_kind: str) -> dict:
    k1, k2 = jax.random.split(key)
    block = {
        "norm1": L.init_rmsnorm(cfg.d_model),
        "mixer": MIXER_INIT[mixer_kind](k1, cfg),
        "norm2": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.rwkv is not None:
        block["mlp"] = L.init_rwkv_cmix(k2, cfg)
    elif mlp_kind == "moe":
        block["mlp"] = L.init_moe(k2, cfg)
    else:
        block["mlp"] = L.init_mlp(k2, cfg)
    return block


def init_lm(key, cfg: ModelConfig) -> dict:
    """Returns a P-pytree (values + logical axes)."""
    plan = cfg.layer_plan()
    period = cfg.layer_period()
    n_periods = cfg.num_layers // period
    keys = jax.random.split(key, 3 + cfg.num_layers)

    params = {
        "embed": dense_init(keys[0], (cfg.padded_vocab, cfg.d_model),
                            ("vocab", "embed"), fan_in=cfg.d_model),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], (cfg.d_model, cfg.padded_vocab),
                                    ("embed", "vocab"), fan_in=cfg.d_model)

    blocks = {}
    for pos in range(period):
        mixer_kind, mlp_kind = plan[pos]
        per_period = [
            _init_block(keys[3 + per * period + pos], cfg, mixer_kind, mlp_kind)
            for per in range(n_periods)
        ]
        blocks[f"pos{pos}"] = stack_layer_params(per_period)
    params["blocks"] = blocks
    return params


def _block_apply(
    block, x, *, cfg: ModelConfig, rt: Runtime, mixer_kind: str, mlp_kind: str,
    mode: str, cache: Optional[dict], pos: Optional[jax.Array],
) -> Tuple[jax.Array, jax.Array, Optional[dict]]:
    """Pre-norm residual block.  Returns (x, aux_loss, new_cache)."""
    use_rope = cfg.attn_period == 0  # hybrids (jamba) carry no explicit PE
    h = L.rmsnorm(block["norm1"], x, cfg.norm_eps, rt)
    mixer_cache = cache.get("mixer") if cache else None
    new_cache = {}
    if mixer_kind == "attn":
        h, mc = L.attention_apply(block["mixer"], h, cfg=cfg, rt=rt, mode=mode,
                                  cache=mixer_cache, pos=pos, use_rope=use_rope)
    elif mixer_kind == "mla":
        h, mc = L.mla_apply(block["mixer"], h, cfg=cfg, rt=rt, mode=mode,
                            cache=mixer_cache, pos=pos)
    elif mixer_kind == "mamba":
        h, mc = L.mamba_apply(block["mixer"], h, cfg=cfg, rt=rt, mode=mode,
                              cache=mixer_cache, pos=pos)
    elif mixer_kind == "rwkv":
        h, mc = L.rwkv_tmix_apply(block["mixer"], h, cfg=cfg, rt=rt, mode=mode,
                                  cache=mixer_cache)
    else:
        raise ValueError(mixer_kind)
    h = checkpoint_name(h, "mixer_out")
    x = x + h
    if mc is not None:
        new_cache["mixer"] = mc

    h = L.rmsnorm(block["norm2"], x, cfg.norm_eps, rt)
    aux = jnp.zeros((), jnp.float32)
    if cfg.rwkv is not None:
        mlp_cache = cache.get("mlp") if cache else None
        h, cc = L.rwkv_cmix_apply(block["mlp"], h, cfg=cfg, rt=rt, mode=mode,
                                  cache=mlp_cache)
        if cc is not None:
            new_cache["mlp"] = cc
    elif mlp_kind == "moe":
        h, aux = L.moe_apply(block["mlp"], h, cfg=cfg, rt=rt)
    else:
        h = L.mlp_apply(block["mlp"], h, cfg=cfg, rt=rt)
    x = x + checkpoint_name(h, "mlp_out")
    return x, aux, (new_cache or None)


def _embed(params, tokens, cfg, rt, image_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(rt.dtype())
    if image_embeds is not None:
        n = image_embeds.shape[1]
        x = jnp.concatenate(
            [image_embeds.astype(x.dtype), x[:, n:]], axis=1
        )
    return shard_hint(x, ("batch", None, "embed_act"))


def _head(params, x, cfg, rt):
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps, rt)
    w = params.get("head")
    if w is None:
        w = params["embed"].T
    logits = x.astype(rt.dtype()) @ w.astype(rt.dtype())
    return shard_hint(logits, ("batch", None, "vocab"))


def forward(
    params,
    tokens: jax.Array,  # (B, S) int32
    *,
    cfg: ModelConfig,
    rt: Runtime,
    mode: str = "full",  # full | prefill
    cache: Optional[dict] = None,
    image_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, Optional[dict]]:
    """Returns (logits, aux_loss, new_cache).

    mode="full":    logits for every position (training).
    mode="prefill": logits for the LAST position only + populated cache.
    """
    plan = cfg.layer_plan()
    period = cfg.layer_period()
    x = _embed(params, tokens, cfg, rt, image_embeds)

    def period_fn(carry, xs):
        x, aux = carry
        blocks_slice, cache_slice = xs
        new_cache_slice = {}
        for pos_i in range(period):
            mixer_kind, mlp_kind = plan[pos_i]
            key = f"pos{pos_i}"
            c = cache_slice.get(key) if cache_slice else None
            x, aux_i, nc = _block_apply(
                blocks_slice[key], x, cfg=cfg, rt=rt,
                mixer_kind=mixer_kind, mlp_kind=mlp_kind,
                mode=mode, cache=c, pos=None,
            )
            aux = aux + aux_i
            if nc is not None:
                new_cache_slice[key] = nc
        return (x, aux), new_cache_slice

    if rt.remat == "full":
        period_fn = jax.checkpoint(period_fn, prevent_cse=False)
    elif rt.remat == "dots":
        period_fn = jax.checkpoint(
            period_fn, policy=jax.checkpoint_policies.dots_saveable,
            prevent_cse=False,
        )
    elif rt.remat == "names":
        period_fn = jax.checkpoint(
            period_fn,
            policy=jax.checkpoint_policies.save_only_these_names(
                "mixer_out", "mlp_out"
            ),
            prevent_cse=False,
        )

    cache_layers = cache["layers"] if cache is not None else None
    (x, aux), new_layer_caches = _scan_periods(
        period_fn, (x, jnp.zeros((), jnp.float32)),
        (params["blocks"], cache_layers), rt,
    )

    new_cache = None
    if mode == "prefill":
        S = tokens.shape[1]
        new_cache = {"pos": jnp.asarray(S, jnp.int32), "layers": new_layer_caches}
        x = x[:, -1:]  # only last-position logits for prefill
    logits = _head(params, x, cfg, rt)
    return logits, aux, new_cache


def decode_step(
    params,
    tokens: jax.Array,  # (B, 1) int32
    cache: dict,
    *,
    cfg: ModelConfig,
    rt: Runtime,
) -> Tuple[jax.Array, dict]:
    """One decode token for the whole batch.  Returns (logits (B,1,V), cache)."""
    plan = cfg.layer_plan()
    period = cfg.layer_period()
    pos = cache["pos"]
    x = _embed(params, tokens, cfg, rt)

    def period_fn(carry, xs):
        x = carry
        blocks_slice, cache_slice = xs
        new_cache_slice = {}
        for pos_i in range(period):
            mixer_kind, mlp_kind = plan[pos_i]
            key = f"pos{pos_i}"
            x, _, nc = _block_apply(
                blocks_slice[key], x, cfg=cfg, rt=rt,
                mixer_kind=mixer_kind, mlp_kind=mlp_kind,
                mode="decode", cache=cache_slice[key], pos=pos,
            )
            new_cache_slice[key] = nc
        return x, new_cache_slice

    x, new_layer_caches = _scan_periods(
        period_fn, x, (params["blocks"], cache["layers"]), rt
    )
    logits = _head(params, x, cfg, rt)
    return logits, {"pos": pos + 1, "layers": new_layer_caches}


# ---------------------------------------------------------------------------
# Cache construction (P-pytree: shardable like params)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    plan = cfg.layer_plan()
    period = cfg.layer_period()
    n_periods = cfg.num_layers // period

    def cache_for(mixer_kind):
        c = {}
        if mixer_kind == "attn":
            c["mixer"] = L.init_attention_cache(cfg, batch, cache_len)
        elif mixer_kind == "mla":
            c["mixer"] = L.init_mla_cache(cfg, batch, cache_len)
        elif mixer_kind == "mamba":
            c["mixer"] = L.init_mamba_cache(cfg, batch)
        elif mixer_kind == "rwkv":
            rc = L.init_rwkv_cache(cfg, batch)
            c["mixer"] = {"x_tmix": rc["x_tmix"], "S": rc["S"]}
            c["mlp"] = {"x_cmix": rc["x_cmix"]}
        return c

    layer_caches = {}
    for pos_i in range(period):
        mixer_kind, _ = plan[pos_i]
        per = [cache_for(mixer_kind) for _ in range(n_periods)]
        layer_caches[f"pos{pos_i}"] = stack_layer_params(per)
    return {"pos": P(jnp.zeros((), jnp.int32), ()), "layers": layer_caches}
