"""Runtime (backend) knobs — the tunable surface of the framework.

These are the JAX/TPU analogues of the paper's TensorFlow threading-model
parameters (DESIGN.md §2).  ``Runtime`` is a frozen dataclass so it is
hashable and can be a static argument of jitted steps; the tuner mutates it
via ``dataclasses.replace``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

_DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32}


@dataclass(frozen=True)
class Runtime:
    # kernel implementation + tile sizes (KMP_BLOCKTIME analogue)
    attn_impl: str = "ref"  # ref | pallas
    scan_impl: str = "chunked"  # ref | chunked | pallas
    block_q: int = 512
    block_kv: int = 512
    scan_chunk: int = 128

    # memory/recompute policy
    remat: str = "none"  # none | dots | full

    # numerics
    compute_dtype: str = "bf16"  # bf16 | f32

    # MoE
    moe_capacity_factor: float = 0.0  # 0 => use config value
    moe_groups: int = 0  # 0 => one group per sequence
    moe_impl: str = "gspmd"  # gspmd (baseline) | ep_local (shard_map EP)

    # causal tile pruning (the Pallas kernel's masked-tile skip, modeled at
    # the HLO level in the unrolled cost path) — a beyond-paper optimization
    attn_prune: bool = False

    # dry-run cost extraction: python-loop over periods instead of lax.scan
    # (XLA's HloCostAnalysis counts while bodies once; the roofline pipeline
    # compiles unrolled 1- and 2-period variants and extrapolates).
    unroll_layers: bool = False

    def dtype(self):
        return _DTYPES[self.compute_dtype]


CPU_TEST = Runtime(compute_dtype="f32", scan_chunk=16, block_q=64, block_kv=64)
