"""Runtime (backend) knobs — the tunable surface of the framework.

These are the JAX/TPU analogues of the paper's TensorFlow threading-model
parameters (DESIGN.md §2).  ``Runtime`` is a frozen dataclass so it is
hashable and can be a static argument of jitted steps; the tuner mutates it
via ``dataclasses.replace``.

``tuning_db`` attaches a persistent :class:`~repro.tuning.tundb.TuningDB`
of best-known kernel configurations: the kernel dispatch layer
(``repro.kernels.ops``) consults it at trace time with the actual call
shapes and overrides the tile knobs below on a hit, falling back to them
on a miss.  ``None`` (the default) is byte-identical to the historical
behavior.  A ``TuningDB`` hashes by identity, so the dataclass stays
hashable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Optional

import jax.numpy as jnp

if TYPE_CHECKING:  # annotation only: models must not depend on the tuning
    from repro.tuning.tundb import TuningDB  # stack at import time

_DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32}

#: The one validated remat vocabulary.  ``BackendConfig`` (the tuner's
#: search space) and ``Runtime`` (the executing backend) must accept
#: exactly the same choices — they drifted once (``"names"`` was tunable
#: but undocumented here), and a drifted enum means the tuner can emit
#: configurations the backend silently mis-handles.  Every choice must
#: lower (pinned by tests/test_config_plumbing.py).
REMAT_MODES = ("none", "dots", "names", "full")


@dataclass(frozen=True)
class Runtime:
    # kernel implementation + tile sizes (KMP_BLOCKTIME analogue)
    attn_impl: str = "ref"  # ref | pallas
    scan_impl: str = "chunked"  # ref | chunked | pallas
    block_q: int = 512
    block_kv: int = 512
    scan_chunk: int = 128

    # memory/recompute policy
    remat: str = "none"  # one of REMAT_MODES: none | dots | names | full

    # numerics
    compute_dtype: str = "bf16"  # bf16 | f32

    # MoE
    moe_capacity_factor: float = 0.0  # 0 => use config value
    moe_groups: int = 0  # 0 => one group per sequence
    moe_impl: str = "gspmd"  # gspmd (baseline) | ep_local (shard_map EP)

    # causal tile pruning (the Pallas kernel's masked-tile skip, modeled at
    # the HLO level in the unrolled cost path) — a beyond-paper optimization
    attn_prune: bool = False

    # dry-run cost extraction: python-loop over periods instead of lax.scan
    # (XLA's HloCostAnalysis counts while bodies once; the roofline pipeline
    # compiles unrolled 1- and 2-period variants and extrapolates).
    unroll_layers: bool = False

    # best-known kernel configs, consulted at trace time (see module
    # docstring); None => heuristic tile defaults above
    tuning_db: Optional["TuningDB"] = None

    def __post_init__(self):
        if self.remat not in REMAT_MODES:
            raise ValueError(
                f"unknown remat mode {self.remat!r}; one of {REMAT_MODES}")

    def dtype(self):
        return _DTYPES[self.compute_dtype]


CPU_TEST = Runtime(compute_dtype="f32", scan_chunk=16, block_q=64, block_kv=64)
