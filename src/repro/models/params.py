"""Parameter pytree helpers.

Models are pure-JAX: ``init`` functions build pytrees whose leaves are
``P(value, axes)`` — the array plus its *logical* sharding axes (names like
"embed", "ff", "heads", "vocab", "experts"; ``None`` = replicated dim).
``split_params`` separates the tree into (values, axes) so apply functions
see plain arrays while the launcher resolves axes → PartitionSpec via
distributed/sharding.py.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class P(NamedTuple):
    value: jax.Array
    axes: Tuple[Optional[str], ...]


def is_p(x) -> bool:
    return isinstance(x, P)


def split_params(tree):
    values = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_p)
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_p)
    return values, axes


def dense_init(
    key: jax.Array,
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    *,
    fan_in: Optional[int] = None,
    scale: float = 1.0,
    dtype=jnp.float32,
) -> P:
    """Truncated-normal init with 1/sqrt(fan_in) scaling."""
    fan_in = fan_in if fan_in is not None else shape[0]
    std = scale / np.sqrt(max(fan_in, 1))
    value = std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)
    return P(value, axes)


def zeros_init(shape, axes, dtype=jnp.float32) -> P:
    return P(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype=jnp.float32) -> P:
    return P(jnp.ones(shape, dtype), axes)


def const_init(value, axes) -> P:
    return P(jnp.asarray(value), axes)


def stack_layer_params(per_layer_trees):
    """Stack a list of identical param trees along a new leading 'layers' dim."""

    def stack(*ps):
        vals = jnp.stack([p.value for p in ps])
        return P(vals, ("layers",) + ps[0].axes)

    return jax.tree_util.tree_map(stack, *per_layer_trees, is_leaf=is_p)
