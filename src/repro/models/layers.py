"""Neural layers for the model zoo (pure JAX, P-pytree params).

Every mixer implements three modes:
  * ``full``    — training forward over the whole sequence (no cache)
  * ``prefill`` — full forward that additionally materializes the decode
                  cache (KV buffers / recurrent states)
  * ``decode``  — one-token step consuming + updating the cache

Apply functions take plain value pytrees (see models/params.py) and a
``Runtime`` for backend knobs.  All matmuls run in ``rt.dtype()``;
softmax/scan statistics in fp32.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_hint
from repro.kernels import ops
from repro.models.params import P, dense_init, ones_init, zeros_init
from repro.models.runtime import Runtime


def _dt(x, rt: Runtime):
    return x.astype(rt.dtype())


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int) -> dict:
    return {"scale": ones_init((d,), (None,))}


def rmsnorm(p, x, eps: float, rt: Runtime) -> jax.Array:
    return ops.rmsnorm(x, p["scale"], eps, db=rt.tuning_db)


def init_layernorm(d: int) -> dict:
    return {"scale": ones_init((d,), (None,)), "bias": zeros_init((d,), (None,))}


def layernorm(p, x, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (B, S, H, dh) rotate-half RoPE; positions (S,) or (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if cos.ndim == 2:  # (S, half) -> broadcast over batch
        cos, sin = cos[None], sin[None]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf1 * sin + xf2 * cos], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (optionally sliding-window)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> dict:
    d, h, k_, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, dh), ("embed", "heads", "head"), fan_in=d),
        "wk": dense_init(ks[1], (d, k_, dh), ("embed", "kv_heads", "head"), fan_in=d),
        "wv": dense_init(ks[2], (d, k_, dh), ("embed", "kv_heads", "head"), fan_in=d),
        "wo": dense_init(ks[3], (h, dh, d), ("heads", "head", "embed"), fan_in=h * dh),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init((h, dh), ("heads", "head"))
        p["bk"] = zeros_init((k_, dh), ("kv_heads", "head"))
        p["bv"] = zeros_init((k_, dh), ("kv_heads", "head"))
    return p


def init_attention_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    k_, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    L = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    return {
        "k": zeros_init((batch, L, k_, dh), ("batch", "cache_seq", "kv_heads", "head"),
                        dtype=jnp.bfloat16),
        "v": zeros_init((batch, L, k_, dh), ("batch", "cache_seq", "kv_heads", "head"),
                        dtype=jnp.bfloat16),
    }


def attention_apply(
    p,
    x: jax.Array,  # (B, S, D)
    *,
    cfg: ModelConfig,
    rt: Runtime,
    mode: str,
    cache: Optional[dict] = None,
    pos: Optional[jax.Array] = None,  # scalar decode position
    use_rope: bool = True,
    causal: bool = True,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn
) -> Tuple[jax.Array, Optional[dict]]:
    B, S, D = x.shape
    h, k_, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    xc = _dt(x, rt)

    q = jnp.einsum("bsd,dhk->bshk", xc, _dt(p["wq"], rt))
    if "bq" in p:
        q = q + _dt(p["bq"], rt)
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", xc, _dt(p["wk"], rt))
        v = jnp.einsum("bsd,dhk->bshk", xc, _dt(p["wv"], rt))
        if "bk" in p:
            k = k + _dt(p["bk"], rt)
            v = v + _dt(p["bv"], rt)
    else:
        k, v = kv_override

    new_cache = None
    if mode in ("full", "prefill"):
        if use_rope and kv_override is None:
            positions = jnp.arange(S)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        q = shard_hint(q, ("batch", None, "heads", None))
        out = ops.attention(
            q, k, v,
            causal=causal,
            window=cfg.sliding_window if causal else None,
            impl=rt.attn_impl,
            block_q=rt.block_q,
            block_kv=rt.block_kv,
            unroll=rt.unroll_layers,
            prune=rt.attn_prune,
            db=rt.tuning_db,
        )
        if mode == "prefill" and kv_override is None:
            new_cache = _fill_kv_cache(cfg, cache, k, v)
    else:  # decode: S == 1
        assert cache is not None and pos is not None
        if use_rope:
            posb = jnp.full((B, 1), pos)
            q = apply_rope(q, posb, cfg.rope_theta)
            k = apply_rope(k, posb, cfg.rope_theta)
        L = cache["k"].shape[1]
        slot = pos % L if cfg.sliding_window else pos
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0)
        )
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0)
        )
        length = jnp.minimum(pos + 1, L)
        lengths = jnp.full((B,), length, jnp.int32)
        out = ops.decode_attention(
            q[:, 0], _dt(ck, rt), _dt(cv, rt), lengths,
            impl=rt.attn_impl, block_kv=rt.block_kv, db=rt.tuning_db,
        )[:, None]
        new_cache = {"k": ck, "v": cv}

    out = jnp.einsum("bshk,hkd->bsd", out, _dt(p["wo"], rt))
    return out.astype(x.dtype), new_cache


def _fill_kv_cache(cfg, cache, k, v):
    """Write prefill K/V into the cache buffer with ring alignment."""
    B, S = k.shape[0], k.shape[1]
    L = cache["k"].shape[1]
    if S >= L:
        ktail, vtail = k[:, S - L:], v[:, S - L:]
        slots = jnp.arange(S - L, S) % L
        ck = cache["k"].at[:, slots].set(ktail.astype(cache["k"].dtype))
        cv = cache["v"].at[:, slots].set(vtail.astype(cache["v"].dtype))
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)
        )
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)
        )
    return {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), ("embed", "lora"), fan_in=d),
        "q_norm": init_rmsnorm(m.q_lora_rank),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, h, qk_head),
                           ("lora", "heads", "head"), fan_in=m.q_lora_rank),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            ("embed", "lora"), fan_in=d),
        "kv_norm": init_rmsnorm(m.kv_lora_rank),
        "wkv_b": dense_init(
            ks[3], (m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim),
            ("lora", "heads", "head"), fan_in=m.kv_lora_rank),
        "wo": dense_init(ks[4], (h, m.v_head_dim, d), ("heads", "head", "embed"),
                         fan_in=h * m.v_head_dim),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    m = cfg.mla
    return {
        "ckv": zeros_init((batch, cache_len, m.kv_lora_rank),
                          ("batch", "cache_seq", "lora"), dtype=jnp.bfloat16),
        "krope": zeros_init((batch, cache_len, m.qk_rope_head_dim),
                            ("batch", "cache_seq", "head"), dtype=jnp.bfloat16),
    }


def mla_apply(
    p, x, *, cfg: ModelConfig, rt: Runtime, mode: str,
    cache: Optional[dict] = None, pos: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    m = cfg.mla
    B, S, D = x.shape
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    scale = (dn + dr) ** -0.5
    xc = _dt(x, rt)

    q_lat = rmsnorm(p["q_norm"], xc @ _dt(p["wq_a"], rt), cfg.norm_eps, rt)
    q = jnp.einsum("bsr,rhk->bshk", _dt(q_lat, rt), _dt(p["wq_b"], rt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    kv_a = xc @ _dt(p["wkv_a"], rt)
    ckv = rmsnorm(p["kv_norm"], kv_a[..., : m.kv_lora_rank], cfg.norm_eps, rt)
    k_rope = kv_a[..., m.kv_lora_rank:]  # (B, S, dr) shared across heads

    if mode in ("full", "prefill"):
        positions = jnp.arange(S)
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        k_rope_r = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
        # expanded (naive) attention for the parallel modes
        kv = jnp.einsum("bsr,rhk->bshk", _dt(ckv, rt), _dt(p["wkv_b"], rt))
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope_r, (*k_nope.shape[:3], dr))],
                            axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = ops.attention(
            qq, k, v, causal=True, scale=scale,
            impl=rt.attn_impl, block_q=rt.block_q, block_kv=rt.block_kv,
            unroll=rt.unroll_layers, prune=rt.attn_prune, db=rt.tuning_db,
        )
        new_cache = None
        if mode == "prefill":
            ck = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0))
            kr = jax.lax.dynamic_update_slice(
                cache["krope"], k_rope_r[:, :, 0].astype(cache["krope"].dtype),
                (0, 0, 0))
            new_cache = {"ckv": ck, "krope": kr}
    else:  # decode — absorbed latent-space attention (the point of MLA)
        posb = jnp.full((B, 1), pos)
        q_rope = apply_rope(q_rope, posb, cfg.rope_theta)
        k_rope_r = apply_rope(k_rope[:, :, None, :], posb, cfg.rope_theta)[:, :, 0]
        ck = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, pos, 0))
        kr = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope_r.astype(cache["krope"].dtype), (0, pos, 0))
        new_cache = {"ckv": ck, "krope": kr}
        wkv_b = _dt(p["wkv_b"], rt)
        w_k, w_v = wkv_b[..., :dn], wkv_b[..., dn:]
        # absorb k-expansion into q: q_eff (B, H, r + dr)
        q_eff = jnp.concatenate(
            [jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], w_k), q_rope[:, 0]], axis=-1
        )
        keys = jnp.concatenate([_dt(ck, rt), _dt(kr, rt)], axis=-1)[:, :, None, :]
        vals = _dt(ck, rt)[:, :, None, :]
        lengths = jnp.full((B,), pos + 1, jnp.int32)
        o_lat = ops.decode_attention(q_eff, keys, vals, lengths, scale=scale,
                                     impl="ref")  # latent kv: ref path
        out = jnp.einsum("bhr,rhv->bhv", o_lat, w_v)[:, None]

    out = jnp.einsum("bshv,hvd->bsd", out, _dt(p["wo"], rt))
    return out.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU or GELU)
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu":
        return {
            "w_up": dense_init(ks[0], (d, f), ("embed", "ff"), fan_in=d),
            "w_down": dense_init(ks[1], (f, d), ("ff", "embed"), fan_in=f),
        }
    return {
        "w_gate": dense_init(ks[0], (d, f), ("embed", "ff"), fan_in=d),
        "w_up": dense_init(ks[1], (d, f), ("embed", "ff"), fan_in=d),
        "w_down": dense_init(ks[2], (f, d), ("ff", "embed"), fan_in=f),
    }


def mlp_apply(p, x, *, cfg: ModelConfig, rt: Runtime) -> jax.Array:
    xc = _dt(x, rt)
    if "w_gate" in p:
        g = jax.nn.silu(xc @ _dt(p["w_gate"], rt))
        u = xc @ _dt(p["w_up"], rt)
        h = shard_hint(g * u, ("batch", None, "ff"))
    else:
        h = jax.nn.gelu(xc @ _dt(p["w_up"], rt))
        h = shard_hint(h, ("batch", None, "ff"))
    return (h @ _dt(p["w_down"], rt)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style grouped capacity dispatch)
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_expert, m.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), ("embed", "experts"), fan_in=d),
        "w_gate": dense_init(ks[1], (e, d, f), ("experts", "embed", "ff"), fan_in=d),
        "w_up": dense_init(ks[2], (e, d, f), ("experts", "embed", "ff"), fan_in=d),
        "w_down": dense_init(ks[3], (e, f, d), ("experts", "ff", "embed"), fan_in=f),
    }


def moe_apply(p, x, *, cfg: ModelConfig, rt: Runtime) -> Tuple[jax.Array, jax.Array]:
    """Returns (out, aux_loss).

    rt.moe_impl:
      * "gspmd" (paper-faithful baseline): grouped capacity dispatch as
        dense scatter/gather einsums; the SPMD partitioner decides the
        collectives.  Measured (EXPERIMENTS.md §Perf): it all-gathers the
        dispatch buffers across the model axis — TBs per step.
      * "ep_local" (beyond-paper): explicit expert parallelism via
        shard_map — activations are replicated across the model axis, each
        shard dispatches only to its local E/tp experts (no communication)
        and the combine is a single bf16 psum of the (B,S,D) output.
    """
    if rt.moe_impl == "ep_local" and _ep_rules_available(cfg):
        return _moe_apply_ep(p, x, cfg=cfg, rt=rt)
    return _moe_apply_gspmd(p, x, cfg=cfg, rt=rt)


def _ep_rules_available(cfg: ModelConfig) -> bool:
    from repro.distributed import sharding as shmod

    rules = getattr(shmod._ACTIVE, "rules", None)
    if rules is None or "model" not in rules.mesh.axis_names:
        return False
    tp = int(rules.mesh.shape["model"])
    return cfg.moe.num_experts % tp == 0


def _moe_apply_ep(p, x, *, cfg: ModelConfig, rt: Runtime):
    """Expert-parallel MoE via shard_map (see moe_apply docstring)."""
    from jax import shard_map
    from jax.sharding import PartitionSpec as PS

    from repro.distributed import sharding as shmod

    rules = shmod._ACTIVE.rules
    mesh = rules.mesh
    tp = int(mesh.shape["model"])
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    E_loc = E // tp
    cf = rt.moe_capacity_factor or m.capacity_factor
    batch_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    sharded_batch = B % rules._axis_size(batch_axes) == 0
    x_spec = PS(batch_axes if sharded_batch else None, None, None)

    def local_moe(xl, router, w_gate, w_up, w_down):
        # xl: (B_loc, S, D) — replicated across "model"; w_*: (E_loc, ...)
        Bl = xl.shape[0]
        G, T = Bl, S
        xg = _dt(xl, rt).reshape(G, T, D)
        logits = (xg @ _dt(router, rt)).astype(jnp.float32)  # full E (repl.)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, K)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        frac_probs = probs.mean(axis=(0, 1))
        assign = jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32)
        aux = E * jnp.sum(frac_probs * assign.mean(axis=(0, 1)))

        C = max(1, int(math.ceil(cf * T * K / E)))
        oh = jax.nn.one_hot(top_i, E, dtype=jnp.int32).reshape(G, T * K, E)
        ranks = jnp.cumsum(oh, axis=1) - oh
        rank_of = jnp.sum(ranks * oh, axis=-1).reshape(G, T, K)
        keep = rank_of < C

        shard = jax.lax.axis_index("model")
        local = (top_i // E_loc) == shard  # expert lives on this shard
        e_loc = top_i % E_loc
        dump = E_loc * C
        dest = jnp.where(keep & local, e_loc * C + rank_of, dump)

        buf = jnp.zeros((G, E_loc * C + 1, D), rt.dtype())
        upd = jnp.repeat(xg, K, axis=1)  # (G, T*K, D) token per slot
        buf = buf.at[jnp.arange(G)[:, None], dest.reshape(G, T * K)].add(upd)
        buf = buf[:, : E_loc * C].reshape(G, E_loc, C, D)

        g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, _dt(w_gate, rt)))
        u = jnp.einsum("gecd,edf->gecf", buf, _dt(w_up, rt))
        y = jnp.einsum("gecf,efd->gecd", g * u, _dt(w_down, rt))

        y_flat = y.reshape(G, E_loc * C, D)
        y_flat = jnp.concatenate([y_flat, jnp.zeros((G, 1, D), y.dtype)], 1)
        gathered = jnp.take_along_axis(
            y_flat, dest.reshape(G, T * K, 1), axis=1
        ).reshape(G, T, K, D)
        w = (top_p * (keep & local)).astype(y.dtype)
        out_local = jnp.einsum("gtkd,gtk->gtd", gathered, w)
        # single combine: bf16 psum across the expert shards
        out = jax.lax.psum(out_local, "model")
        return out.reshape(Bl, S, D), aux

    out, aux = shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(x_spec, PS(), PS("model"), PS("model"), PS("model")),
        out_specs=(x_spec, PS()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out.astype(x.dtype), aux


def _moe_apply_gspmd(
    p, x, *, cfg: ModelConfig, rt: Runtime
) -> Tuple[jax.Array, jax.Array]:
    """Paper-faithful GSPMD einsum/scatter dispatch (see moe_apply)."""
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    cf = rt.moe_capacity_factor or m.capacity_factor

    G = rt.moe_groups or B
    T = (B * S) // G
    xg = x.reshape(G, T, D)
    xc = _dt(xg, rt)

    logits = (xc @ _dt(p["router"], rt)).astype(jnp.float32)  # (G, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)  # (G, T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * mean(frac_tokens * frac_probs)
    frac_probs = probs.mean(axis=(0, 1))  # (E,)
    assign = jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32)
    frac_tokens = assign.mean(axis=(0, 1))
    aux = E * jnp.sum(frac_probs * frac_tokens)

    C = max(1, int(math.ceil(cf * T * K / E)))

    # rank of each (token, slot) within its expert, group-local
    oh = jax.nn.one_hot(top_i, E, dtype=jnp.int32)  # (G, T, K, E)
    oh_flat = oh.reshape(G, T * K, E)
    ranks = jnp.cumsum(oh_flat, axis=1) - oh_flat  # exclusive
    rank_of = jnp.sum(ranks * oh_flat, axis=-1).reshape(G, T, K)
    keep = rank_of < C

    dump = E * C  # overflow slot
    dest = jnp.where(keep, top_i * C + rank_of, dump)  # (G, T, K)

    # dispatch: scatter tokens into (G, E*C+1, D) buffers
    buf = jnp.zeros((G, E * C + 1, D), rt.dtype())
    tok_idx = jnp.broadcast_to(jnp.arange(T)[None, :, None], (G, T, K))
    upd = jnp.take_along_axis(
        xc, tok_idx.reshape(G, T * K, 1).clip(0, T - 1), axis=1
    )
    buf = buf.at[jnp.arange(G)[:, None], dest.reshape(G, T * K)].add(upd)
    buf = buf[:, : E * C].reshape(G, E, C, D)
    buf = shard_hint(buf, ("batch", "experts", None, None))

    # expert FFN (SwiGLU)
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, _dt(p["w_gate"], rt)))
    u = jnp.einsum("gecd,edf->gecf", buf, _dt(p["w_up"], rt))
    h = shard_hint(g * u, ("batch", "experts", None, "ff"))
    y = jnp.einsum("gecf,efd->gecd", h, _dt(p["w_down"], rt))
    y = shard_hint(y, ("batch", "experts", None, None))

    # combine: gather each slot's output, weight, sum over k
    y_flat = y.reshape(G, E * C, D)
    y_flat = jnp.concatenate([y_flat, jnp.zeros((G, 1, D), y.dtype)], axis=1)
    gathered = jnp.take_along_axis(
        y_flat, dest.reshape(G, T * K, 1), axis=1
    ).reshape(G, T, K, D)
    w = (top_p * keep).astype(y.dtype)
    out = jnp.einsum("gtkd,gtk->gtd", gathered, w)
    return out.reshape(B, S, D).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Mamba-1 block (Jamba's SSM mixer)
# ---------------------------------------------------------------------------


def init_mamba(key, cfg: ModelConfig) -> dict:
    mc = cfg.mamba
    d = cfg.d_model
    d_in = mc.expand * d
    dtr = mc.resolved_dt_rank(d)
    N = mc.d_state
    ks = jax.random.split(key, 6)
    # S4D-real A init: A[d, n] = -(n + 1)
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (d_in, N))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in), ("embed", "ff"), fan_in=d),
        "conv_w": dense_init(ks[1], (mc.d_conv, d_in), (None, "ff"), fan_in=mc.d_conv),
        "conv_b": zeros_init((d_in,), ("ff",)),
        "x_proj": dense_init(ks[2], (d_in, dtr + 2 * N), ("ff", None), fan_in=d_in),
        "dt_w": dense_init(ks[3], (dtr, d_in), (None, "ff"), fan_in=dtr),
        "dt_b": P(jnp.log(jnp.expm1(0.01 * jnp.ones(d_in))), ("ff",)),
        "A_log": P(jnp.log(A), ("ff", None)),
        "D": ones_init((d_in,), ("ff",)),
        "out_proj": dense_init(ks[4], (d_in, d), ("ff", "embed"), fan_in=d_in),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int) -> dict:
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    return {
        "conv": zeros_init((batch, mc.d_conv - 1, d_in), ("batch", None, "state")),
        "h": zeros_init((batch, d_in, mc.d_state), ("batch", "state", None)),
    }


def _mamba_ssm_inputs(p, xz, cfg, rt):
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    dtr = mc.resolved_dt_rank(cfg.d_model)
    N = mc.d_state
    x, z = xz[..., :d_in], xz[..., d_in:]
    return x, z, dtr, N, d_in


def mamba_apply(
    p, x, *, cfg: ModelConfig, rt: Runtime, mode: str,
    cache: Optional[dict] = None, pos: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    mc = cfg.mamba
    B, S, D = x.shape
    xc = _dt(x, rt)
    xz = xc @ _dt(p["in_proj"], rt)  # (B, S, 2*d_in)
    xs, z, dtr, N, d_in = _mamba_ssm_inputs(p, xz, cfg, rt)

    conv_w = _dt(p["conv_w"], rt)  # (d_conv, d_in)
    if mode in ("full", "prefill"):
        pad = jnp.zeros((B, mc.d_conv - 1, d_in), xs.dtype)
        xpad = jnp.concatenate([pad, xs], axis=1)
        xconv = sum(
            xpad[:, i : i + S] * conv_w[i][None, None] for i in range(mc.d_conv)
        ) + _dt(p["conv_b"], rt)
    else:
        xprev = _dt(cache["conv"], rt)  # (B, d_conv-1, d_in)
        xpad = jnp.concatenate([xprev, xs], axis=1)  # (B, d_conv, 1? S=1)
        xconv = jnp.einsum("bcd,cd->bd", xpad, conv_w)[:, None] + _dt(p["conv_b"], rt)
    xconv = jax.nn.silu(xconv)

    xdbl = xconv @ _dt(p["x_proj"], rt)
    dt_raw, Bc, Cc = (
        xdbl[..., :dtr], xdbl[..., dtr : dtr + N], xdbl[..., dtr + N :],
    )
    dt = jax.nn.softplus(dt_raw @ _dt(p["dt_w"], rt) + _dt(p["dt_b"], rt))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    new_cache = None
    if mode == "full":
        y = ops.ssm_scan(xconv, dt, A, Bc, Cc, p["D"],
                         impl=rt.scan_impl, chunk=rt.scan_chunk,
                         db=rt.tuning_db)
    elif mode == "prefill":
        from repro.kernels.ref import ssm_scan_chunked_ref

        y, h_final = ssm_scan_chunked_ref(
            xconv, dt, A, Bc, Cc, p["D"], chunk=rt.scan_chunk
        )
        conv_state = jnp.concatenate(
            [jnp.zeros((B, mc.d_conv - 1, d_in), xs.dtype), xs], axis=1
        )[:, -(mc.d_conv - 1):]
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype),
                     "h": h_final.astype(cache["h"].dtype)}
    else:  # decode: one recurrence step
        h = cache["h"].astype(jnp.float32)  # (B, d_in, N)
        dtt = dt[:, 0].astype(jnp.float32)
        xt = xconv[:, 0].astype(jnp.float32)
        Bt, Ct = Bc[:, 0].astype(jnp.float32), Cc[:, 0].astype(jnp.float32)
        h = jnp.exp(dtt[..., None] * A[None]) * h + (dtt * xt)[..., None] * Bt[:, None]
        y = (jnp.einsum("bdn,bn->bd", h, Ct)
             + xt * p["D"].astype(jnp.float32))[:, None]
        conv_state = jnp.concatenate([cache["conv"], xs.astype(cache["conv"].dtype)],
                                     axis=1)[:, 1:]
        new_cache = {"conv": conv_state, "h": h.astype(cache["h"].dtype)}

    y = _dt(y, rt) * jax.nn.silu(z)
    out = y @ _dt(p["out_proj"], rt)
    return out.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# RWKV-6 "Finch" time-mix + channel-mix
# ---------------------------------------------------------------------------


def init_rwkv_tmix(key, cfg: ModelConfig) -> dict:
    rc = cfg.rwkv
    d = cfg.d_model
    H = d // rc.head_size
    ks = jax.random.split(key, 10)
    return {
        "mu": zeros_init((5, d), (None, None)),  # static ddlerp mix for w,k,v,r,g
        "mix_w1": dense_init(ks[0], (d, 5 * rc.mix_lora), ("embed", None), fan_in=d,
                             scale=0.1),
        "mix_w2": dense_init(ks[1], (5, rc.mix_lora, d), (None, None, "embed"),
                             fan_in=rc.mix_lora, scale=0.1),
        "w_lora1": dense_init(ks[2], (d, rc.decay_lora), ("embed", None), fan_in=d,
                              scale=0.1),
        "w_lora2": dense_init(ks[3], (rc.decay_lora, d), (None, "embed"),
                              fan_in=rc.decay_lora, scale=0.1),
        "w_bias": P(jnp.full((d,), -6.0 + 5.0 * (jnp.arange(d) / max(d - 1, 1))),
                    ("embed",)),
        "wr": dense_init(ks[4], (d, d), ("embed", "heads"), fan_in=d),
        "wk": dense_init(ks[5], (d, d), ("embed", "heads"), fan_in=d),
        "wv": dense_init(ks[6], (d, d), ("embed", "heads"), fan_in=d),
        "wg": dense_init(ks[7], (d, d), ("embed", "heads"), fan_in=d),
        "wo": dense_init(ks[8], (d, d), ("heads", "embed"), fan_in=d),
        "u": dense_init(ks[9], (H, rc.head_size), ("heads", None), fan_in=1,
                        scale=0.5),
        "ln_x": init_layernorm(d),
    }


def init_rwkv_cmix(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": zeros_init((d,), (None,)),
        "mu_r": zeros_init((d,), (None,)),
        "wk": dense_init(ks[0], (d, f), ("embed", "ff"), fan_in=d),
        "wv": dense_init(ks[1], (f, d), ("ff", "embed"), fan_in=f),
        "wr": dense_init(ks[2], (d, d), ("embed", None), fan_in=d),
    }


def init_rwkv_cache(cfg: ModelConfig, batch: int) -> dict:
    rc = cfg.rwkv
    d = cfg.d_model
    H = d // rc.head_size
    return {
        "x_tmix": zeros_init((batch, d), ("batch", None)),
        "x_cmix": zeros_init((batch, d), ("batch", None)),
        "S": zeros_init((batch, H, rc.head_size, rc.head_size),
                        ("batch", "heads", None, None)),
    }


def _token_shift(x, x_prev_last):
    """prev-token shift: returns x_{t-1} sequence.  x (B,S,D)."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x_prev_last is not None:
        shifted = shifted.at[:, 0].set(x_prev_last.astype(x.dtype))
    return shifted


def rwkv_tmix_apply(
    p, x, *, cfg: ModelConfig, rt: Runtime, mode: str,
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    rc = cfg.rwkv
    B, S, D = x.shape
    H = D // rc.head_size
    hs = rc.head_size
    xc = _dt(x, rt)

    x_last = cache["x_tmix"] if cache is not None else None
    x_prev = _token_shift(xc, x_last)
    dx = x_prev - xc

    # data-dependent ddlerp for the five streams
    mix_base = xc + dx * _dt(p["mu"], rt)[:, None, None]  # (5, B, S, D) broadcast
    lora = jnp.tanh(xc @ _dt(p["mix_w1"], rt)).reshape(B, S, 5, rc.mix_lora)
    lora = jnp.einsum("bsfm,fmd->fbsd", lora, _dt(p["mix_w2"], rt))
    xw, xk, xv, xr, xg = [mix_base[i] + dx * lora[i] for i in range(5)]

    r = (xr @ _dt(p["wr"], rt)).reshape(B, S, H, hs)
    k = (xk @ _dt(p["wk"], rt)).reshape(B, S, H, hs)
    v = (xv @ _dt(p["wv"], rt)).reshape(B, S, H, hs)
    g = jax.nn.silu(xg @ _dt(p["wg"], rt))

    w_raw = (jnp.tanh(xw @ _dt(p["w_lora1"], rt)) @ _dt(p["w_lora2"], rt)
             + _dt(p["w_bias"], rt))
    w = jnp.exp(-jnp.exp(w_raw.astype(jnp.float32))).reshape(B, S, H, hs)
    u = p["u"].astype(jnp.float32)

    new_cache = None
    if mode == "full":
        y = ops.gla_scan(r, k, v, w.astype(r.dtype), u.astype(r.dtype),
                         impl=rt.scan_impl, chunk=rt.scan_chunk,
                         db=rt.tuning_db)
    elif mode == "prefill":
        from repro.kernels.ref import gla_scan_chunked_ref

        y, S_final = gla_scan_chunked_ref(
            r, k, v, w.astype(r.dtype), u.astype(r.dtype), chunk=rt.scan_chunk
        )
        new_cache = {"x_tmix": xc[:, -1].astype(cache["x_tmix"].dtype),
                     "S": S_final.astype(cache["S"].dtype)}
    else:  # decode: single recurrence step
        Sst = cache["S"].astype(jnp.float32)  # (B,H,hs,hs)
        rt_, kt, vt = (a[:, 0].astype(jnp.float32) for a in (r, k, v))
        wt = w[:, 0]
        bonus = jnp.einsum("bhk,hk,bhk->bh", rt_, u, kt)
        y = (jnp.einsum("bhk,bhkv->bhv", rt_, Sst)
             + bonus[..., None] * vt)[:, None]
        S_new = wt[..., None] * Sst + kt[..., None] * vt[:, :, None, :]
        new_cache = {"x_tmix": xc[:, 0].astype(cache["x_tmix"].dtype),
                     "S": S_new.astype(cache["S"].dtype)}
        y = y.astype(r.dtype)

    y = y.reshape(B, S, D)
    y = layernorm(p["ln_x"], y, 1e-5)  # per-layer output norm (rwkv ln_x)
    out = (_dt(y, rt) * g) @ _dt(p["wo"], rt)
    return out.astype(x.dtype), new_cache


def rwkv_cmix_apply(
    p, x, *, cfg: ModelConfig, rt: Runtime, mode: str,
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    xc = _dt(x, rt)
    x_last = cache["x_cmix"] if cache is not None else None
    x_prev = _token_shift(xc, x_last)
    dx = x_prev - xc
    xk = xc + dx * _dt(p["mu_k"], rt)
    xr = xc + dx * _dt(p["mu_r"], rt)
    k = jnp.square(jax.nn.relu(xk @ _dt(p["wk"], rt)))
    k = shard_hint(k, ("batch", None, "ff"))
    kv = k @ _dt(p["wv"], rt)
    out = jax.nn.sigmoid(xr @ _dt(p["wr"], rt)) * kv
    new_cache = None
    if cache is not None:
        new_cache = {"x_cmix": xc[:, -1].astype(cache["x_cmix"].dtype)}
    return out.astype(x.dtype), new_cache
