"""Encoder-decoder transformer (Whisper-style) with stubbed conv frontend.

The audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed mel-frame embeddings ``encoder_embeds (B, T_enc, D)``.
LayerNorm + GELU + biased attention projections per Whisper; positional
encoding is sinusoidal for both stacks (Whisper's decoder table is learned
and capped at 448 positions — sinusoids let the framework exercise the
assigned 32k/500k decode shapes; deviation recorded in DESIGN.md).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers as L
from repro.models.params import P, dense_init, stack_layer_params, zeros_init
from repro.models.lm import _scan_periods
from repro.models.runtime import Runtime


def sinusoids(length: int, channels: int) -> np.ndarray:
    assert channels % 2 == 0
    log_timescale = np.log(10_000.0) / (channels // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(channels // 2))
    t = np.arange(length)[:, None] * inv[None, :]
    return np.concatenate([np.sin(t), np.cos(t)], axis=1).astype(np.float32)


def _pos_enc(positions: jax.Array, channels: int) -> jax.Array:
    half = channels // 2
    log_timescale = np.log(10_000.0) / (half - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half))
    t = positions.astype(jnp.float32)[..., None] * inv
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=-1)


def _init_enc_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": L.init_layernorm(cfg.d_model),
        "attn": L.init_attention(k1, cfg),
        "norm2": L.init_layernorm(cfg.d_model),
        "mlp": L.init_mlp(k2, cfg),
    }


def _init_dec_block(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": L.init_layernorm(cfg.d_model),
        "self_attn": L.init_attention(k1, cfg),
        "norm_c": L.init_layernorm(cfg.d_model),
        "cross_attn": L.init_attention(k2, cfg),
        "norm2": L.init_layernorm(cfg.d_model),
        "mlp": L.init_mlp(k3, cfg),
    }


def init_encdec(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 2 + cfg.encoder_layers + cfg.num_layers)
    params = {
        "embed": dense_init(keys[0], (cfg.padded_vocab, cfg.d_model),
                            ("vocab", "embed"), fan_in=cfg.d_model),
        "enc_blocks": stack_layer_params(
            [_init_enc_block(keys[2 + i], cfg) for i in range(cfg.encoder_layers)]
        ),
        "enc_norm": L.init_layernorm(cfg.d_model),
        "dec_blocks": stack_layer_params(
            [_init_dec_block(keys[2 + cfg.encoder_layers + i], cfg)
             for i in range(cfg.num_layers)]
        ),
        "final_norm": L.init_layernorm(cfg.d_model),
    }
    return params


def encode(params, encoder_embeds: jax.Array, *, cfg: ModelConfig, rt: Runtime):
    """encoder_embeds (B, T_enc, D) — stub frontend output."""
    B, T, D = encoder_embeds.shape
    x = encoder_embeds.astype(rt.dtype()) + _pos_enc(jnp.arange(T), D).astype(rt.dtype())

    def block_fn(x, blk):
        h, _ = L.attention_apply(
            blk["attn"], L.layernorm(blk["norm1"], x, cfg.norm_eps),
            cfg=cfg, rt=rt, mode="full", use_rope=False, causal=False,
        )
        x = x + h
        x = x + L.mlp_apply(blk["mlp"], L.layernorm(blk["norm2"], x, cfg.norm_eps),
                            cfg=cfg, rt=rt)
        return x, None

    x, _ = _scan_periods(block_fn, x, params["enc_blocks"], rt)
    return L.layernorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_kv(blk, enc_out, rt):
    k = jnp.einsum("btd,dhk->bthk", enc_out.astype(rt.dtype()),
                   blk["cross_attn"]["wk"].astype(rt.dtype()))
    v = jnp.einsum("btd,dhk->bthk", enc_out.astype(rt.dtype()),
                   blk["cross_attn"]["wv"].astype(rt.dtype()))
    if "bk" in blk["cross_attn"]:
        k = k + blk["cross_attn"]["bk"].astype(k.dtype)
        v = v + blk["cross_attn"]["bv"].astype(v.dtype)
    return k, v


def _cross_attend(blk, x, k, v, *, cfg, rt, mode):
    p = blk["cross_attn"]
    q = jnp.einsum("bsd,dhk->bshk", x.astype(rt.dtype()), p["wq"].astype(rt.dtype()))
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    if mode == "decode":
        lengths = jnp.full((x.shape[0],), k.shape[1], jnp.int32)
        out = ops.decode_attention(q[:, 0], k.astype(rt.dtype()),
                                   v.astype(rt.dtype()), lengths,
                                   impl=rt.attn_impl, block_kv=rt.block_kv,
                                   db=rt.tuning_db)[:, None]
    else:
        out = ops.attention(q, k.astype(rt.dtype()), v.astype(rt.dtype()),
                            causal=False, impl=rt.attn_impl,
                            block_q=rt.block_q, block_kv=rt.block_kv,
                            unroll=rt.unroll_layers, db=rt.tuning_db)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(rt.dtype())).astype(x.dtype)


def forward(
    params,
    tokens: jax.Array,  # (B, S) decoder tokens
    encoder_embeds: jax.Array,  # (B, T_enc, D)
    *,
    cfg: ModelConfig,
    rt: Runtime,
    mode: str = "full",  # full | prefill
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, jax.Array, Optional[dict]]:
    B, S = tokens.shape
    D = cfg.d_model
    enc_out = encode(params, encoder_embeds, cfg=cfg, rt=rt)

    x = jnp.take(params["embed"], tokens, axis=0).astype(rt.dtype())
    x = x + _pos_enc(jnp.arange(S), D).astype(x.dtype)

    def block_fn(carry, xs):
        x = carry
        blk, cache_slice = xs
        h, kv = L.attention_apply(
            blk["self_attn"], L.layernorm(blk["norm1"], x, cfg.norm_eps),
            cfg=cfg, rt=rt,
            mode=("prefill" if mode == "prefill" else "full"),
            cache=(cache_slice["self"] if cache_slice else None),
            use_rope=False, causal=True,
        )
        x = x + h
        ck, cv = _cross_kv(blk, enc_out, rt)
        x = x + _cross_attend(blk, L.layernorm(blk["norm_c"], x, cfg.norm_eps),
                              ck, cv, cfg=cfg, rt=rt, mode="full")
        x = x + L.mlp_apply(blk["mlp"], L.layernorm(blk["norm2"], x, cfg.norm_eps),
                            cfg=cfg, rt=rt)
        new_cache = None
        if mode == "prefill":
            new_cache = {
                "self": kv,
                "cross": {"k": ck.astype(jnp.bfloat16), "v": cv.astype(jnp.bfloat16)},
            }
        return x, new_cache

    cache_layers = cache["layers"] if cache is not None else None
    x, new_layer_caches = _scan_periods(
        block_fn, x, (params["dec_blocks"], cache_layers), rt
    )

    new_cache = None
    if mode == "prefill":
        new_cache = {"pos": jnp.asarray(S, jnp.int32), "layers": new_layer_caches}
        x = x[:, -1:]
    x = L.layernorm(params["final_norm"], x, cfg.norm_eps)
    logits = x.astype(rt.dtype()) @ params["embed"].T.astype(rt.dtype())
    return logits, jnp.zeros((), jnp.float32), new_cache


def decode_step(
    params, tokens: jax.Array, cache: dict, *, cfg: ModelConfig, rt: Runtime
) -> Tuple[jax.Array, dict]:
    pos = cache["pos"]
    B = tokens.shape[0]
    D = cfg.d_model
    x = jnp.take(params["embed"], tokens, axis=0).astype(rt.dtype())
    x = x + _pos_enc(jnp.full((B, 1), pos), D).astype(x.dtype)

    def block_fn(carry, xs):
        x = carry
        blk, cache_slice = xs
        h, kv = L.attention_apply(
            blk["self_attn"], L.layernorm(blk["norm1"], x, cfg.norm_eps),
            cfg=cfg, rt=rt, mode="decode", cache=cache_slice["self"], pos=pos,
            use_rope=False, causal=True,
        )
        x = x + h
        x = x + _cross_attend(
            blk, L.layernorm(blk["norm_c"], x, cfg.norm_eps),
            cache_slice["cross"]["k"], cache_slice["cross"]["v"],
            cfg=cfg, rt=rt, mode="decode",
        )
        x = x + L.mlp_apply(blk["mlp"], L.layernorm(blk["norm2"], x, cfg.norm_eps),
                            cfg=cfg, rt=rt)
        return x, {"self": kv, "cross": cache_slice["cross"]}

    x, new_layer_caches = _scan_periods(
        block_fn, x, (params["dec_blocks"], cache["layers"]), rt
    )
    x = L.layernorm(params["final_norm"], x, cfg.norm_eps)
    logits = x.astype(rt.dtype()) @ params["embed"].T.astype(rt.dtype())
    return logits, {"pos": pos + 1, "layers": new_layer_caches}


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    h, dh = cfg.num_heads, cfg.resolved_head_dim
    per = []
    for _ in range(cfg.num_layers):
        per.append({
            "self": L.init_attention_cache(cfg, batch, cache_len),
            "cross": {
                "k": zeros_init((batch, cfg.encoder_seq_len, h, dh),
                                ("batch", "cache_seq", "heads", "head"),
                                dtype=jnp.bfloat16),
                "v": zeros_init((batch, cfg.encoder_seq_len, h, dh),
                                ("batch", "cache_seq", "heads", "head"),
                                dtype=jnp.bfloat16),
            },
        })
    return {
        "pos": P(jnp.zeros((), jnp.int32), ()),
        "layers": stack_layer_params(per),
    }
