"""Model facade: one object per architecture config.

Wraps the family-specific init/apply/cache functions behind a uniform
interface used by the trainer, server, dry-run, benchmarks and tuner:

    model = build_model(get_config("qwen2-0.5b"))
    params = model.init(key)                      # P-pytree
    logits, aux, _ = model.apply(values, batch, rt=rt)
    cache = model.init_cache(batch=8, cache_len=1024)
    logits, cache = model.decode_step(values, tok, cache_values, rt=rt)

``input_specs(shape)`` returns ShapeDtypeStruct stand-ins + logical axes
for every model input — the dry-run lowers against these without
allocating anything.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, lm
from repro.models.runtime import Runtime


@dataclass(frozen=True)
class InputSpec:
    struct: jax.ShapeDtypeStruct
    logical_axes: Tuple[Optional[str], ...]


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.is_encdec = cfg.encoder_layers > 0

    # -- params / cache -----------------------------------------------------
    def init(self, key) -> dict:
        if self.is_encdec:
            return encdec.init_encdec(key, self.cfg)
        return lm.init_lm(key, self.cfg)

    def init_cache(self, batch: int, cache_len: int) -> dict:
        if self.is_encdec:
            return encdec.init_cache(self.cfg, batch, cache_len)
        return lm.init_cache(self.cfg, batch, cache_len)

    # -- compute ------------------------------------------------------------
    def apply(
        self,
        params,
        batch: Dict[str, jax.Array],
        *,
        rt: Runtime,
        mode: str = "full",
        cache: Optional[dict] = None,
    ):
        """Returns (logits, aux_loss, new_cache)."""
        if self.is_encdec:
            return encdec.forward(
                params, batch["tokens"], batch["encoder_embeds"],
                cfg=self.cfg, rt=rt, mode=mode, cache=cache,
            )
        return lm.forward(
            params, batch["tokens"], cfg=self.cfg, rt=rt, mode=mode,
            cache=cache, image_embeds=batch.get("image_embeds"),
        )

    def decode_step(self, params, tokens, cache, *, rt: Runtime):
        if self.is_encdec:
            return encdec.decode_step(params, tokens, cache, cfg=self.cfg, rt=rt)
        return lm.decode_step(params, tokens, cache, cfg=self.cfg, rt=rt)

    # -- shape stand-ins ----------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, InputSpec]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        specs: Dict[str, InputSpec] = {}
        if shape.kind == "decode":
            specs["tokens"] = InputSpec(
                jax.ShapeDtypeStruct((B, 1), jnp.int32), ("batch", None)
            )
        else:
            specs["tokens"] = InputSpec(
                jax.ShapeDtypeStruct((B, S), jnp.int32), ("batch", None)
            )
        if shape.kind == "train":
            specs["targets"] = InputSpec(
                jax.ShapeDtypeStruct((B, S), jnp.int32), ("batch", None)
            )
        if cfg.family == "vlm" and shape.kind != "decode":
            specs["image_embeds"] = InputSpec(
                jax.ShapeDtypeStruct((B, cfg.num_frontend_tokens, cfg.d_model),
                                     jnp.bfloat16),
                ("batch", None, None),
            )
        if self.is_encdec and shape.kind != "decode":
            specs["encoder_embeds"] = InputSpec(
                jax.ShapeDtypeStruct((B, cfg.encoder_seq_len, cfg.d_model),
                                     jnp.bfloat16),
                ("batch", None, None),
            )
        return specs


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
